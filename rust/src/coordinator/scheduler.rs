//! Iteration-level continuous-batching scheduler: step-level admission,
//! chunked prefill, mixed prefill+decode waves, speculative verify chains,
//! in-flight completion — the coordination pattern of vLLM/Sarathi-class
//! servers, driven synchronously so it is unit-testable without threads.
//!
//! ## Why iteration-level
//!
//! The Split-Brain contract makes the host the sole owner of dynamic state,
//! so host-side scheduling is the throughput lever. The pre-chunking
//! scheduler ran every admitted prompt's prefill to completion inside one
//! scheduling iteration: a single 2k-token prompt froze every in-flight
//! decode behind ~250 device waves. This scheduler instead decides work
//! **per iteration**:
//!
//! 1. **admit** newly arrived requests (no device work — they enter the
//!    prefill chunk queue with their cached prefix already grafted);
//! 2. compose one **mixed iteration**: one decode row for every decoding
//!    sequence, plus up to [`SchedulerOpts::prefill_chunk_tokens`] prompt
//!    rows of still-prefilling sequences (FCFS);
//! 3. run the rows through the compiled buckets
//!    ([`plan_mixed`](super::batcher::plan_mixed)), sample decode rows and
//!    any sequence whose prefill completed, harvest finished requests.
//!
//! Chunking never changes outputs: prefill is deterministic in absolute
//! position and every row's attention sees only its own sequence's KV, so
//! the KV a chunked prefill builds is bit-identical to a whole prefill —
//! the same property [`KvSnapshot`](crate::host::kv_cache::KvSnapshot)
//! by-reference restores already rely on. Pinned by
//! `rust/tests/continuous_batching_sim.rs`.
//!
//! ## Speculative decoding
//!
//! A scheduler built over [`CartridgeEngines::with_draft`] additionally
//! runs the [`spec`](super::spec) propose→verify loop: each greedy decoding
//! sequence's single decode row becomes a **verify chain** of up to
//! `SpecOpts::depth + 1` rows (the pending token plus the draft's
//! proposals) riding the same mixed waves, and the accepted prefix lands
//! several tokens per iteration. Rejected rows roll back inside the step,
//! so exports, checkpoints, and migrations never observe draft state, and
//! greedy outputs stay byte-identical to a draft-less run
//! (`rust/tests/spec_decode_sim.rs`).
//!
//! ## KV memory tiers
//!
//! [`KvMemOpts`] adds two capacity levers (`docs/kv-memory-tiers.md`):
//! cold KV pages beyond a hot window are block-quantized in place
//! (INT8/INT4, dequantized on read), and a resident-byte budget backed by
//! a disk spill tier ([`KvSpill`]) pages whole idle sequences out when the
//! cache runs over, restoring them before their next decode step. Both
//! default off; with the defaults every existing byte-identity
//! differential holds unchanged, and spill round-trips are byte-identical
//! on their own (`rust/tests/kv_spill_sim.rs`). Periodic decode
//! checkpoints ship as a full-snapshot-then-deltas chain
//! ([`Scheduler::decode_checkpoints`]), so steady-state checkpoint cost is
//! O(tokens per interval) rather than O(context).
//!
//! [`CartridgeEngines::with_draft`]: super::spec::CartridgeEngines::with_draft
//! [`SpecOpts::depth`]: super::spec::SpecOpts::depth
//!
//! # Example
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the libxla rpath; the same flow
//! // is pinned by the unit and integration tests)
//! use ita::config::ModelConfig;
//! use ita::coordinator::engine::Engine;
//! use ita::coordinator::request::GenRequest;
//! use ita::coordinator::scheduler::{Scheduler, SchedulerOpts};
//!
//! let engine = Engine::synthetic(&ModelConfig::TINY, 7);
//! let mut sched = Scheduler::new(engine, SchedulerOpts::default());
//! sched.submit(GenRequest::greedy(0, "hello ita", 8));
//! let results = sched.run_to_completion().unwrap();
//! assert_eq!(results.len(), 1);
//! println!("{}", sched.metrics().report());
//! ```

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{plan_pipeline, BatchStats};
use super::engine::Engine;
use super::metrics::ServingMetrics;
use super::request::{
    CheckpointUpdate, DecodeCheckpoint, FinishReason, GenRequest, GenResult, KvCheckpoint,
};
use super::spec::{CartridgeEngines, SpecDecoder, SpecOpts, VerifyOutcome};
use super::trace::{TraceEvent, TraceKind, TraceRecorder, WAVE_NONE};
use crate::host::kv_cache::{KvQuantPolicy, KvQuantTag, KvSnapshotDelta, SeqId};
use crate::host::kv_spill::KvSpill;
use crate::host::sampling::sample;
use crate::host::tokenizer::{ByteTokenizer, EOS};
use crate::util::prng::Prng;

/// Scheduler options.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerOpts {
    /// Max concurrently active sequences — prefilling plus decoding
    /// (0 → device max bucket).
    pub max_active: usize,
    /// Sampling seed (deterministic serving).
    pub seed: u64,
    /// Radix prefix-cache page budget (0 = prefill reuse disabled). With a
    /// budget, admitted prompts are matched against previously served ones
    /// and the matched prefix skips device prefill entirely — its KV pages
    /// are shared copy-on-write. Outputs are bit-identical either way.
    pub prefix_cache_pages: usize,
    /// Per-iteration prefill token budget (chunked prefill). Each
    /// scheduling iteration carries at most this many prompt rows alongside
    /// the decode rows, so one long prompt can no longer stall every
    /// in-flight decode behind its prefill; the decode inter-token gap is
    /// bounded by roughly `budget / max_bucket` extra waves per iteration.
    /// 0 = run-to-completion: a prompt's entire uncached suffix prefills in
    /// the iteration it is admitted (the pre-chunking behaviour). Greedy
    /// outputs are byte-identical for every budget.
    pub prefill_chunk_tokens: usize,
    /// Speculative-decoding configuration. Only takes effect when the
    /// scheduler was built with a draft engine
    /// ([`Scheduler::with_engines`] over
    /// [`CartridgeEngines::with_draft`]); `depth: 0` disables speculation
    /// even then. Greedy outputs are byte-identical either way.
    pub spec: SpecOpts,
    /// Request-lifecycle trace ring capacity (events). 0 disables tracing
    /// entirely — every instrumentation site reduces to one inlined bool
    /// load, no timestamps are taken, nothing allocates (the bench sweep's
    /// `tracing_overhead` record pins this). When the ring fills between
    /// worker drains, the oldest events are dropped and counted.
    pub trace_capacity: usize,
    /// Shared trace clock origin. The fleet injects one epoch before
    /// spawning workers so cross-cartridge timestamps are comparable;
    /// `None` (the standalone default) anchors at scheduler construction.
    pub trace_epoch: Option<Instant>,
    /// Buffer committed tokens per step for streaming delivery
    /// ([`Scheduler::take_streamed`]). The front door turns these into
    /// per-request token streams; off (the default) nothing is buffered
    /// and completion-only serving pays nothing.
    pub stream_tokens: bool,
    /// KV memory tiering: cold-page quantization and the disk spill tier
    /// (`docs/kv-memory-tiers.md`). The defaults keep every byte-identity
    /// differential intact: FP32 pages, no budget, no spill.
    pub kv_mem: KvMemOpts,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        SchedulerOpts {
            max_active: 0,
            seed: 0x17A,
            prefix_cache_pages: 8192,
            prefill_chunk_tokens: 64,
            spec: SpecOpts::default(),
            trace_capacity: 0,
            trace_epoch: None,
            stream_tokens: false,
            kv_mem: KvMemOpts::default(),
        }
    }
}

/// KV memory-tier options (`docs/kv-memory-tiers.md`): cold-page block
/// quantization inside the paged cache, and a byte budget backed by the
/// disk spill tier. All default off; with the defaults every output is
/// byte-identical to a build without these features.
#[derive(Debug, Clone, Copy)]
pub struct KvMemOpts {
    /// Storage encoding for cold KV pages ([`KvQuantTag::Fp32`] = off).
    /// Quantized reads change logits within the bound pinned by
    /// `rust/tests/kv_quant_sim.rs`; greedy argmax streams stay identical
    /// on the sim workloads.
    pub quant: KvQuantTag,
    /// Trailing tokens always kept FP32 (the quantization hot window).
    pub hot_window: usize,
    /// Resident KV byte budget (0 = unlimited). With [`spill`] set, going
    /// over budget pages whole idle sequences' KV to disk; they are
    /// restored — byte-identically, when quantization is off — before
    /// their next decode step.
    ///
    /// [`spill`]: KvMemOpts::spill
    pub budget_bytes: usize,
    /// Enable the disk spill tier ([`KvSpill`]). Without it the budget is
    /// advisory (reported, never enforced).
    pub spill: bool,
}

impl Default for KvMemOpts {
    fn default() -> Self {
        KvMemOpts { quant: KvQuantTag::Fp32, hot_window: 64, budget_bytes: 0, spill: false }
    }
}

struct Active {
    req: GenRequest,
    seq: SeqId,
    /// full tokenized prompt (kept for prefix-cache publication)
    prompt: Vec<u32>,
    /// leading tokens served from the prefix cache (no prefill ran)
    skipped: usize,
    /// prompt rows committed so far — the prefill cursor. Starts at
    /// `skipped` (the grafted prefix) and the sequence decodes once it
    /// reaches `prompt.len()`: the final prompt row always runs through the
    /// device so its logits exist to sample the first token from.
    prefilled: usize,
    generated: Vec<u32>,
    /// tokens inherited from a checkpoint restore (0 for fresh requests);
    /// this cartridge's ITL accounting excludes them — their decode time
    /// was spent elsewhere
    resumed_len: usize,
    /// last sampled token (input for the next decode step)
    next_token: u32,
    /// draft tokens proposed / accepted for this request (speculative
    /// decoding telemetry; both 0 without a draft engine)
    spec_proposed: u64,
    spec_accepted: u64,
    /// chain id of the last periodic checkpoint emitted for this request
    /// (0 = none yet → the next checkpoint ships a full snapshot; nonzero →
    /// it ships only the rows appended since as a [`KvSnapshotDelta`])
    ckpt_id: u64,
    /// committed KV rows covered by checkpoint `ckpt_id`
    ckpt_len: usize,
    enqueued: Instant,
    /// when admission pulled this request off the queue (queue-wait end;
    /// the trace splits E2E into a Queued and an Active span here)
    admitted: Instant,
    first_token_at: Option<Instant>,
    /// when the previous token was sampled (per-token gap accounting —
    /// [`ServingMetrics::itl_step`] samples are measured from here)
    last_token_at: Option<Instant>,
}

impl Active {
    fn finished(&self) -> bool {
        (self.req.stop_at_eos && self.generated.last() == Some(&EOS))
            || self.generated.len() >= self.req.max_new_tokens
    }

    /// Prefill complete — this sequence contributes a decode row.
    fn decoding(&self) -> bool {
        self.prefilled == self.prompt.len()
    }
}

/// What one device row of a mixed iteration is for: a decode step of
/// sequence `active[i]`, one row of its speculative verify chain (the
/// pending token followed by the draft proposals, contiguous and in
/// ascending position order), or one prompt position of its prefill chunk.
#[derive(Clone, Copy)]
enum Row {
    Decode(usize),
    Verify(usize),
    Prefill(usize),
}

/// One admission-queue entry: a fresh request awaiting prefill, or a
/// checkpointed request awaiting a KV restore (migration / panic resume).
enum QueueEntry {
    Fresh(GenRequest, Instant),
    Resume(GenRequest, Box<DecodeCheckpoint>, Instant),
}

impl QueueEntry {
    fn id(&self) -> u64 {
        match self {
            QueueEntry::Fresh(r, _) | QueueEntry::Resume(r, _, _) => r.id,
        }
    }
}

/// A decoding sequence whose KV currently lives in the spill file: the
/// full [`Active`] bookkeeping minus its engine pages (`a.seq` is stale —
/// the restore allocates a fresh sequence and rewrites it).
struct SpilledSeq {
    a: Active,
    /// spill-file bytes held (the snapshot's wire size)
    bytes: usize,
}

/// Synchronous continuous-batching scheduler over one engine (plus an
/// optional draft engine for speculative decoding).
pub struct Scheduler {
    engine: Engine,
    /// Draft side of speculative decoding (None = no draft engine, or
    /// `opts.spec.depth == 0`).
    spec: Option<SpecDecoder>,
    tokenizer: ByteTokenizer,
    queue: VecDeque<QueueEntry>,
    active: Vec<Active>,
    rng: Prng,
    opts: SchedulerOpts,
    batch_stats: BatchStats,
    metrics: ServingMetrics,
    started: Instant,
    /// Request-lifecycle event ring (no-op unless
    /// [`SchedulerOpts::trace_capacity`] > 0).
    trace: TraceRecorder,
    /// Monotone wave sequence number — the join key between `Wave` spans
    /// and the `Tokens` events attributing committed tokens to them.
    wave_seq: u64,
    /// Tokens committed since the last [`take_streamed`](Self::take_streamed)
    /// drain, per wire ticket — only populated when
    /// [`SchedulerOpts::stream_tokens`] is on.
    streamed: Vec<(u64, Vec<u32>)>,
    /// Modeled energy per MAC (pJ) for the ITA operating point
    /// ([`EnergyParams::ita`](crate::energy::EnergyParams::ita)); scales
    /// device MAC counts into [`ServingMetrics::energy_j`].
    pj_per_mac: f64,
    /// Disk spill tier (Some iff [`KvMemOpts::spill`] and a nonzero
    /// budget; falls back to None — budget unenforced — if the backing
    /// file cannot be created).
    spill: Option<KvSpill>,
    /// Sequences currently paged out, oldest first (restore order).
    spilled: Vec<SpilledSeq>,
    /// Monotone checkpoint-chain id source (0 is reserved for "none").
    next_ckpt_id: u64,
}

impl Scheduler {
    pub fn new(engine: Engine, opts: SchedulerOpts) -> Scheduler {
        Scheduler::with_engines(CartridgeEngines::from(engine), opts)
    }

    /// Build over a target engine optionally paired with a draft engine
    /// ([`CartridgeEngines::with_draft`]): greedy requests then decode
    /// speculatively — the draft proposes up to [`SpecOpts::depth`] tokens
    /// per iteration and the target verifies them in one batched chain.
    /// A draft whose vocabulary differs from the target's cannot propose
    /// meaningful token ids; it is rejected with a warning and the
    /// scheduler runs draft-less (outputs are identical either way).
    pub fn with_engines(engines: CartridgeEngines, opts: SchedulerOpts) -> Scheduler {
        let CartridgeEngines { target: mut engine, draft } = engines;
        let max = if opts.max_active == 0 { engine.max_batch() } else { opts.max_active };
        if opts.prefix_cache_pages > 0 {
            engine.enable_prefix_cache(opts.prefix_cache_pages);
        }
        if opts.kv_mem.quant != KvQuantTag::Fp32 {
            engine.set_kv_quant(KvQuantPolicy {
                tag: opts.kv_mem.quant,
                hot_window: opts.kv_mem.hot_window,
            });
        }
        let spill = if opts.kv_mem.spill && opts.kv_mem.budget_bytes > 0 {
            match KvSpill::new() {
                Ok(sp) => Some(sp),
                Err(e) => {
                    eprintln!("[ita-scheduler] spill tier unavailable ({e:#}); budget unenforced");
                    None
                }
            }
        } else {
            None
        };
        let spec = match draft {
            Some(d) if opts.spec.depth > 0 => {
                if d.dims().vocab == engine.dims().vocab {
                    Some(SpecDecoder::new(d, opts.spec))
                } else {
                    eprintln!(
                        "[ita-spec] draft vocab {} != target vocab {}; speculation disabled",
                        d.dims().vocab,
                        engine.dims().vocab
                    );
                    None
                }
            }
            _ => None,
        };
        let trace = if opts.trace_capacity > 0 {
            TraceRecorder::new(opts.trace_capacity, opts.trace_epoch.unwrap_or_else(Instant::now))
        } else {
            TraceRecorder::disabled()
        };
        Scheduler {
            engine,
            spec,
            tokenizer: ByteTokenizer::new(),
            queue: VecDeque::new(),
            active: Vec::with_capacity(max),
            rng: Prng::new(opts.seed),
            opts: SchedulerOpts { max_active: max, ..opts },
            batch_stats: BatchStats::default(),
            metrics: ServingMetrics::default(),
            started: Instant::now(),
            trace,
            wave_seq: 0,
            streamed: Vec::new(),
            pj_per_mac: crate::energy::EnergyParams::default().ita().total_pj(),
            spill,
            spilled: Vec::new(),
            next_ckpt_id: 0,
        }
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.submit_at(req, Instant::now());
    }

    /// Submit with an explicit enqueue time — the fleet dispatcher passes
    /// the instant the request entered the shared admission queue, so TTFT
    /// and total latency include dispatcher-queue wait (and, for requeued
    /// requests, the time lost on a dead cartridge).
    pub fn submit_at(&mut self, req: GenRequest, enqueued: Instant) {
        self.queue.push_back(QueueEntry::Fresh(req, enqueued));
    }

    /// Enqueue a checkpointed request: admission restores its KV snapshot
    /// (by reference where this cartridge's radix cache still holds the
    /// promised prompt prefix, by value otherwise) and resumes decode at
    /// the checkpointed step instead of re-prefilling.
    pub fn submit_resume(&mut self, req: GenRequest, ckpt: DecodeCheckpoint, enqueued: Instant) {
        self.queue.push_back(QueueEntry::Resume(req, Box::new(ckpt), enqueued));
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len() + self.spilled.len()
    }

    /// Rows actively decoding right now (excludes queued and spilled
    /// sequences). Rides worker checkpoints into the fleet's live status
    /// surface as per-cartridge occupancy.
    pub fn active_rows(&self) -> usize {
        self.active.len()
    }

    /// Resolved concurrent-decode capacity (the fleet dispatcher caps each
    /// worker's outstanding requests at this).
    pub fn capacity(&self) -> usize {
        self.opts.max_active
    }

    /// One scheduling iteration: admit newly arrived requests, compose a
    /// mixed wave set — one decode row (or speculative verify chain) per
    /// decoding sequence plus prefill chunk rows under the token budget —
    /// run it, sample, and harvest completions.
    pub fn step(&mut self) -> Result<Vec<GenResult>> {
        let mut done = self.admit();
        self.enforce_kv_budget();
        if self.active.is_empty() {
            return Ok(done);
        }

        // compose this iteration's device rows: decode/verify rows first
        // (every decoding sequence advances at least one token), then
        // prefill-chunk rows under the token budget, FCFS over
        // still-prefilling sequences
        let mut ids: Vec<SeqId> = Vec::new();
        let mut tokens: Vec<u32> = Vec::new();
        let mut rows: Vec<Row> = Vec::new();
        let mut drafts: Vec<Vec<u32>> = vec![Vec::new(); self.active.len()];
        for i in 0..self.active.len() {
            if !self.active[i].decoding() {
                continue;
            }
            let (seq, next) = (self.active[i].seq, self.active[i].next_token);
            if let Some(spec) = self.spec.as_mut() {
                let a = &self.active[i];
                // only greedy requests speculate (acceptance is exact token
                // equality; stochastic sampling would need distribution-
                // preserving rejection sampling), and only while more than
                // one token of budget remains
                let remaining = a.req.max_new_tokens.saturating_sub(a.generated.len());
                if a.req.sampling.temperature <= 0.0 && remaining > 1 {
                    match spec.propose(seq, &a.prompt, &a.generated, remaining - 1) {
                        Ok(d) => {
                            if self.trace.enabled() && !d.is_empty() {
                                let mut ev =
                                    TraceEvent::at(self.trace.now_us(), TraceKind::SpecPropose);
                                ev.req = a.req.id;
                                ev.a = d.len() as u64;
                                self.trace.record(ev);
                            }
                            drafts[i] = d;
                        }
                        // a draft-engine failure degrades that sequence to
                        // plain decode; the target engine is untouched
                        Err(e) => eprintln!(
                            "[ita-spec] draft proposal failed for request {}: {e:#}; \
                             plain decode",
                            a.req.id
                        ),
                    }
                }
            }
            ids.push(seq);
            tokens.push(next);
            if drafts[i].is_empty() {
                rows.push(Row::Decode(i));
            } else {
                rows.push(Row::Verify(i));
                for &t in &drafts[i] {
                    ids.push(seq);
                    tokens.push(t);
                    rows.push(Row::Verify(i));
                }
            }
        }
        let decode_rows = rows.len();
        let mut budget = match self.opts.prefill_chunk_tokens {
            0 => usize::MAX, // run-to-completion: the whole suffix, now
            n => n,
        };
        for (i, a) in self.active.iter().enumerate() {
            if budget == 0 {
                break;
            }
            let remaining = a.prompt.len() - a.prefilled;
            if remaining == 0 {
                continue;
            }
            let take = remaining.min(budget);
            for &tok in &a.prompt[a.prefilled..a.prefilled + take] {
                ids.push(a.seq);
                tokens.push(tok);
                rows.push(Row::Prefill(i));
            }
            budget -= take;
            self.metrics.prefill_chunks += 1;
            if self.trace.enabled() {
                let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::PrefillChunk);
                ev.req = a.req.id;
                ev.a = take as u64;
                ev.b = (a.prefilled + take) as u64;
                self.trace.record(ev);
            }
        }

        // stage-aware plan: rows compose into waves exactly as before; on a
        // pipelined engine the waves additionally stream over the K stages
        // (stage k+1 overlapping stage k), which the occupancy telemetry
        // tracks. K=1 degenerates to the plain mixed plan.
        let buckets = self.engine.bucket_sizes();
        let p = plan_pipeline(
            decode_rows,
            rows.len() - decode_rows,
            &buckets,
            self.engine.n_stages(),
        );
        self.batch_stats.record_pipeline(&p);

        // run the waves; sample decode rows and the final prompt row of
        // any sequence whose prefill completes this iteration, exactly as
        // before speculation existed. Rows of one sequence stay in
        // ascending position order across waves, and the engine commits
        // each wave before the next, so a chunk (or verify chain) split
        // across waves resumes at the committed absolute position. Only
        // VERIFY rows buffer their logits past the wave loop: acceptance
        // must walk a whole chain in order, and a chain may span waves —
        // everything else samples inline, so the draft-less hot path pays
        // no extra copies. Verify sampling is greedy (it never draws from
        // the RNG), so deferring it cannot shift the RNG stream of
        // stochastic rows.
        let mut sampled: Vec<(usize, Vec<u32>, bool)> = Vec::new(); // (idx, tokens, first)
        let mut chains: Vec<Vec<Vec<f32>>> = vec![Vec::new(); self.active.len()];
        // per verify row, the wave it rode (aligned with `chains[i]`) — the
        // join key that later attributes each accepted token to its wave
        let tracing = self.trace.enabled();
        let mut chain_waves: Vec<Vec<u64>> =
            if tracing { vec![Vec::new(); self.active.len()] } else { Vec::new() };
        let mut offset = 0;
        for w in &p.mixed.plan.waves {
            let end = offset + w.rows;
            // wave span bookkeeping: deltas of the engine's cumulative MAC
            // and modeled-link counters bound this wave's energy/link share
            let (t0, macs0, link0) = if tracing {
                (
                    self.trace.now_us(),
                    self.engine.device_stats().macs,
                    self.engine.link_stats().modeled_time_s,
                )
            } else {
                (0, 0, 0.0)
            };
            let logits = self.engine.forward(&ids[offset..end], &tokens[offset..end])?;
            let wid = if tracing {
                self.wave_seq += 1;
                let wid = self.wave_seq;
                let dur = self.trace.now_us().saturating_sub(t0).max(1);
                let link_us = ((self.engine.link_stats().modeled_time_s - link0) * 1e6)
                    .round()
                    .max(0.0) as u64;
                let macs = self.engine.device_stats().macs - macs0;
                let mut ev = TraceEvent::at(t0, TraceKind::Wave);
                ev.dur_us = dur;
                ev.wave = wid;
                ev.a = w.bucket as u64;
                ev.b = w.rows as u64;
                ev.link_us = link_us;
                ev.energy_j = macs as f64 * self.pj_per_mac * 1e-12;
                self.trace.record(ev);
                // pipelined engine: modeled per-stage slices of the wave
                let layers = self.engine.stage_layers();
                if layers.len() > 1 {
                    let spans = super::pipeline::stage_spans(dur, link_us, &layers);
                    for (s, (off, d)) in spans.into_iter().enumerate() {
                        let mut sev = TraceEvent::at(t0 + off, TraceKind::StageSpan);
                        sev.dur_us = d;
                        sev.wave = wid;
                        sev.a = s as u64;
                        self.trace.record(sev);
                    }
                }
                wid
            } else {
                WAVE_NONE
            };
            let v = logits.cols;
            for r in 0..w.rows {
                let row = &logits.data[r * v..(r + 1) * v];
                match rows[offset + r] {
                    Row::Decode(i) => {
                        let tok = sample(row, &self.active[i].req.sampling, &mut self.rng);
                        sampled.push((i, vec![tok], false));
                        if tracing {
                            let mut ev =
                                TraceEvent::at(self.trace.now_us(), TraceKind::Tokens);
                            ev.req = self.active[i].req.id;
                            ev.wave = wid;
                            ev.a = 1;
                            self.trace.record(ev);
                        }
                    }
                    Row::Verify(i) => {
                        chains[i].push(row.to_vec());
                        if tracing {
                            chain_waves[i].push(wid);
                        }
                    }
                    Row::Prefill(i) => {
                        self.active[i].prefilled += 1;
                        self.metrics.tokens_prefilled += 1;
                        if self.active[i].decoding() {
                            // final prompt row: its logits seed the stream
                            let tok = sample(row, &self.active[i].req.sampling, &mut self.rng);
                            sampled.push((i, vec![tok], true));
                            if tracing {
                                let mut ev =
                                    TraceEvent::at(self.trace.now_us(), TraceKind::Tokens);
                                ev.req = self.active[i].req.id;
                                ev.wave = wid;
                                ev.a = 1;
                                self.trace.record(ev);
                            }
                        }
                    }
                }
            }
            offset = end;
        }

        // acceptance per verify chain: the accepted draft prefix plus the
        // target's correction/bonus token joins the stream; rejected rows
        // roll back inside accept_verified
        for i in 0..chains.len() {
            if chains[i].is_empty() {
                continue;
            }
            let (p0, a0) = (self.active[i].spec_proposed, self.active[i].spec_accepted);
            let out = self.accept_verified(i, &drafts[i], &chains[i])?;
            if tracing {
                let rid = self.active[i].req.id;
                let now = self.trace.now_us();
                let dp = self.active[i].spec_proposed - p0;
                let da = self.active[i].spec_accepted - a0;
                let mut acc = TraceEvent::at(now, TraceKind::SpecAccept);
                acc.req = rid;
                acc.a = da;
                acc.b = dp;
                self.trace.record(acc);
                if dp > da {
                    let mut rb = TraceEvent::at(now, TraceKind::SpecRollback);
                    rb.req = rid;
                    rb.a = dp - da;
                    self.trace.record(rb);
                }
                // attribute the committed tokens to the wave(s) whose rows
                // produced them: token j came from verify row j, and a
                // chain may span waves
                let waves = &chain_waves[i];
                let mut j = 0;
                while j < out.len() {
                    let wid = waves[j];
                    let mut k = j + 1;
                    while k < out.len() && waves[k] == wid {
                        k += 1;
                    }
                    let mut tev = TraceEvent::at(now, TraceKind::Tokens);
                    tev.req = rid;
                    tev.wave = wid;
                    tev.a = (k - j) as u64;
                    self.trace.record(tev);
                    j = k;
                }
            }
            sampled.push((i, out, false));
        }

        // apply sampled tokens; publish freshly completed prefills
        let now = Instant::now();
        for (i, toks, first) in &sampled {
            let n = toks.len() as u64;
            self.metrics.tokens_generated += n;
            let a = &mut self.active[*i];
            if self.opts.stream_tokens {
                self.streamed.push((a.req.id, toks.clone()));
            }
            a.generated.extend_from_slice(toks);
            a.next_token = *toks.last().expect("sampled entries are non-empty");
            if *first {
                a.first_token_at = Some(now);
                self.metrics.ttft.record(now.duration_since(a.enqueued).as_secs_f64());
                // prefill just completed: publish the prompt's KV for
                // cross-request reuse
                self.engine.register_prefix(a.seq, &a.prompt);
            } else if let Some(prev) = a.last_token_at {
                // one gap sample per accepted token, not per wave: a
                // verify chain landing n tokens at once records n gaps of
                // wave_time / n, so ITL percentiles stay comparable
                // between speculative and vanilla runs
                let gap = now.duration_since(prev).as_secs_f64() / n as f64;
                for _ in 0..n {
                    self.metrics.itl_step.record(gap);
                }
            }
            a.last_token_at = Some(now);
        }

        self.harvest(&mut done, now);
        Ok(done)
    }

    /// Walk one sequence's verify-chain logits: greedily sample each row
    /// in order, accept draft tokens while the target agrees, stop at the
    /// first disagreement (the target's own sample is the correction) or
    /// after the last row (the bonus token). The emitted chain is exactly
    /// the greedy chain `tokenᵢ₊₁ = argmax(logits after tokens ..ᵢ)`, so
    /// outputs are byte-identical to vanilla decode by construction. Clips
    /// at EOS / the token budget precisely where sequential decode would
    /// have stopped, rolls the rejected rows out of the target KV, and
    /// reconciles the draft shadow. Returns the tokens to append (≥ 1).
    fn accept_verified(
        &mut self,
        i: usize,
        draft: &[u32],
        chain: &[Vec<f32>],
    ) -> Result<Vec<u32>> {
        debug_assert_eq!(chain.len(), draft.len() + 1);
        let a = &self.active[i];
        let mut out: Vec<u32> = Vec::with_capacity(chain.len());
        for (j, logits) in chain.iter().enumerate() {
            let tok = sample(logits, &a.req.sampling, &mut self.rng);
            out.push(tok);
            if !(j < draft.len() && tok == draft[j]) {
                break;
            }
        }
        // matched draft prefix; the final element is the target's own
        // correction (mismatch) or bonus (all matched) token
        let matched = out.len() - 1;
        // stop conditions, applied exactly where sequential decode stops
        if a.req.stop_at_eos {
            if let Some(pos) = out.iter().position(|&t| t == EOS) {
                out.truncate(pos + 1);
            }
        }
        out.truncate(a.req.max_new_tokens.saturating_sub(a.generated.len()));
        debug_assert!(!out.is_empty(), "decoding sequences always have budget >= 1");
        let applied = out.len();
        // of the applied tokens, those matching the draft were accepted;
        // conservation (proposed == accepted + rejected) holds by
        // construction and is pinned by rust/tests/spec_decode_sim.rs
        let accepted = matched.min(applied);
        let proposed = draft.len();
        let stream_len = a.prompt.len() + a.generated.len();
        let seq = a.seq;
        // the waves committed proposed + 1 rows for this sequence; only
        // `applied` belong to the new stream (its newest token is sampled
        // but not yet consumed — the standard decode invariant), so roll
        // the rest back without disturbing shared/COW pages
        self.engine.truncate_sequence(seq, stream_len + applied - 1)?;
        self.metrics.spec_proposed += proposed as u64;
        self.metrics.spec_accepted += accepted as u64;
        self.metrics.spec_rollbacks += (proposed - accepted) as u64;
        self.metrics.spec_accept.record(accepted as f64 / proposed.max(1) as f64);
        if let Some(spec) = self.spec.as_mut() {
            spec.observe(seq, VerifyOutcome { stream_len, applied, accepted, proposed })?;
        }
        let a = &mut self.active[i];
        a.spec_proposed += proposed as u64;
        a.spec_accepted += accepted as u64;
        Ok(out)
    }

    /// Sweep completed requests out of the active set. Stable removal, so
    /// `active` stays in admission order — which is what makes both the
    /// decode-row composition and the prefill chunk budget genuinely FCFS.
    fn harvest(&mut self, done: &mut Vec<GenResult>, now: Instant) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].first_token_at.is_some() && self.active[i].finished() {
                let a = self.active.remove(i);
                done.push(self.finish(a, now));
            } else {
                i += 1;
            }
        }
    }

    /// Drive until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Step-level admission, up to capacity. Fresh requests enter the
    /// prefill chunk queue with their longest cached prefix grafted — no
    /// device work happens here; their prefill is spread over the following
    /// iterations. Checkpointed requests restore their KV inline and rejoin
    /// decode at the checkpointed step. Returns any restored request that
    /// is already at its token limit.
    fn admit(&mut self) -> Vec<GenResult> {
        let mut resumed_any = false;
        while self.active.len() < self.opts.max_active {
            let Some(entry) = self.queue.pop_front() else { break };
            match entry {
                QueueEntry::Fresh(req, enqueued) => {
                    let now = Instant::now();
                    self.metrics.queue_wait.record(now.duration_since(enqueued).as_secs_f64());
                    let prompt = self.tokenizer.encode(&req.prompt);
                    // graft the longest cached prefix; only the suffix will
                    // prefill, chunk by chunk
                    let (seq, skipped) = self.engine.new_sequence_with_prefix(&prompt);
                    self.metrics.prefill_skipped_tokens += skipped as u64;
                    if self.trace.enabled() {
                        let mut ev = TraceEvent::at(self.trace.ts_us(now), TraceKind::Admit);
                        ev.req = req.id;
                        ev.a = now.duration_since(enqueued).as_micros() as u64;
                        ev.b = prompt.len() as u64;
                        self.trace.record(ev);
                    }
                    self.active.push(Active {
                        prefilled: skipped,
                        prompt,
                        skipped,
                        req,
                        seq,
                        generated: Vec::new(),
                        resumed_len: 0,
                        next_token: 0, // set when the final prompt row samples
                        spec_proposed: 0,
                        spec_accepted: 0,
                        ckpt_id: 0,
                        ckpt_len: 0,
                        enqueued,
                        admitted: now,
                        first_token_at: None,
                        last_token_at: None,
                    });
                }
                QueueEntry::Resume(req, ckpt, enqueued) => {
                    self.resume(req, *ckpt, enqueued);
                    resumed_any = true;
                }
            }
        }
        // a restored checkpoint can already be at its token limit
        let mut done = Vec::new();
        if resumed_any {
            self.harvest(&mut done, Instant::now());
        }
        done
    }

    /// Rebuild a checkpointed request: restore its KV (by reference through
    /// the radix cache where promised, by value otherwise) and rejoin the
    /// decode set at the checkpointed step. If the promised prefix was
    /// evicted between probe and restore, fall back to a plain re-prefill —
    /// deterministic decode regenerates the same stream either way.
    fn resume(&mut self, req: GenRequest, ckpt: DecodeCheckpoint, enqueued: Instant) {
        let DecodeCheckpoint { prompt, generated, kv, spec_proposed, spec_accepted } = ckpt;
        if generated.is_empty() {
            // defensive: a checkpoint without a sampled token has no decode
            // state worth restoring
            self.queue.push_front(QueueEntry::Fresh(req, enqueued));
            return;
        }
        let seq = match self.engine.restore_sequence(&kv, &prompt) {
            Ok(seq) => seq,
            Err(e) => {
                eprintln!(
                    "[ita-scheduler] checkpoint restore for request {} failed ({e:#}); \
                     re-prefilling",
                    req.id
                );
                self.queue.push_front(QueueEntry::Fresh(req, enqueued));
                return;
            }
        };
        self.metrics.restored_tokens += kv.value_rows() as u64;
        self.metrics.prefill_skipped_tokens += kv.by_ref_len as u64;
        self.metrics.resumed_requests += 1;
        // publish the (fully restored) prompt for future prefix reuse on
        // this cartridge — a second migration of it then travels by-ref
        self.engine.register_prefix(seq, &prompt);
        let next = *generated.last().expect("checked non-empty above");
        let now = Instant::now();
        // the requeue/migration round-trip is queue wait too — recovery
        // latency shows up in the queue-wait percentiles, not just TTFT
        self.metrics.queue_wait.record(now.duration_since(enqueued).as_secs_f64());
        if self.trace.enabled() {
            let mut ev = TraceEvent::at(self.trace.ts_us(now), TraceKind::Resume);
            ev.req = req.id;
            ev.a = kv.value_rows() as u64;
            ev.b = kv.by_ref_len as u64;
            self.trace.record(ev);
        }
        // time-to-resumed-service: keeps recovery latency visible in the
        // pooled TTFT percentiles (a dead cartridge's genuine sample was
        // stripped with its checkpoint; after a live migration this is one
        // extra sample for the request — visibility over exact counts)
        self.metrics.ttft.record(now.duration_since(enqueued).as_secs_f64());
        self.active.push(Active {
            skipped: prompt.len(), // nothing re-prefilled here
            prefilled: prompt.len(),
            prompt,
            req,
            seq,
            next_token: next,
            resumed_len: generated.len(),
            generated,
            // speculation telemetry survives the move — GenResult reports
            // end-to-end totals for the request, not per-cartridge slices
            spec_proposed,
            spec_accepted,
            // the delta chain does not survive a move between schedulers:
            // the first checkpoint here re-ships a full snapshot
            ckpt_id: 0,
            ckpt_len: 0,
            enqueued,
            admitted: now,
            first_token_at: Some(now),
            last_token_at: Some(now),
        });
    }

    /// Enforce [`KvMemOpts::budget_bytes`] around this step: first wake
    /// spilled sequences that fit back under the budget (or, if nothing is
    /// active at all, the oldest one unconditionally — spilled work must
    /// not deadlock behind a too-small budget), then page out the newest
    /// decoding sequences until the resident bytes fit. The last active
    /// sequence is never spilled, so every step makes decode progress and
    /// the forced wake cannot ping-pong.
    fn enforce_kv_budget(&mut self) {
        if self.spill.is_none() {
            return;
        }
        let budget = self.opts.kv_mem.budget_bytes;
        // wake path: oldest first, FCFS like admission
        while !self.spilled.is_empty() {
            let forced = self.active.is_empty();
            let fits = self.active.len() < self.opts.max_active
                && self.engine.kv_resident_bytes() + self.spilled[0].bytes <= budget;
            if !forced && !fits {
                break;
            }
            self.unspill_front();
            if forced {
                break; // one at a time when over budget; it decodes first
            }
        }
        // spill path: newest decoding sequence out first (the oldest are
        // closest to completion — evicting them last keeps FCFS latency)
        while self.engine.kv_resident_bytes() > budget && self.active.len() > 1 {
            let Some(i) = self.active.iter().rposition(|a| !a.generated.is_empty()) else {
                break; // only mid-prefill sequences left: nothing to spill
            };
            if !self.spill_to_disk(i) {
                break;
            }
        }
    }

    /// Page `active[i]`'s KV out to the spill file. Returns false (leaving
    /// the sequence active) if the write failed — over-budget is better
    /// than losing decode state.
    fn spill_to_disk(&mut self, i: usize) -> bool {
        let seq = self.active[i].seq;
        let snap = self.engine.snapshot_seq(seq, 0).expect("active sequences snapshot cleanly");
        let ticket = self.active[i].req.id;
        let bytes = match self.spill.as_mut().expect("caller checked").spill(ticket, &snap) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("[ita-scheduler] spill of request {ticket} failed ({e:#}); kept resident");
                return false;
            }
        };
        // stable removal: `active` stays in admission order
        let a = self.active.remove(i);
        if let Some(spec) = self.spec.as_mut() {
            spec.drop_seq(a.seq);
        }
        self.engine.free_sequence(a.seq);
        self.metrics.kv_spills += 1;
        self.metrics.kv_spill_bytes += bytes as u64;
        if self.trace.enabled() {
            let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Spill);
            ev.req = ticket;
            ev.a = snap.len as u64;
            ev.b = bytes as u64;
            self.trace.record(ev);
        }
        self.spilled.push(SpilledSeq { a, bytes });
        true
    }

    /// Restore the oldest spilled sequence into the engine and return it
    /// to the active set. Spill + restore round-trips the exact snapshot
    /// bytes, so with quantization off the sequence's subsequent decode is
    /// byte-identical to never having been spilled (restored pages start
    /// FP32 either way; a quantizing cache re-quantizes them on the next
    /// cold sweep).
    fn unspill_front(&mut self) {
        let SpilledSeq { mut a, bytes } = self.spilled.remove(0);
        let ticket = a.req.id;
        let restored = self
            .spill
            .as_mut()
            .expect("spilled entries imply a spill tier")
            .restore(ticket)
            .and_then(|snap| self.engine.restore_sequence(&snap, &a.prompt));
        match restored {
            Ok(seq) => {
                a.seq = seq;
                self.metrics.kv_unspills += 1;
                self.metrics.kv_unspill_bytes += bytes as u64;
                if self.trace.enabled() {
                    let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Unspill);
                    ev.req = ticket;
                    ev.a = self.engine.seq_len(seq) as u64;
                    ev.b = bytes as u64;
                    self.trace.record(ev);
                }
                self.active.push(a);
            }
            Err(e) => {
                // disk or restore failure: degrade to a plain re-prefill —
                // deterministic decode regenerates the same stream
                eprintln!(
                    "[ita-scheduler] unspill of request {ticket} failed ({e:#}); re-prefilling"
                );
                self.queue.push_front(QueueEntry::Fresh(a.req, a.enqueued));
            }
        }
    }

    /// Resident KV bytes across the engine's stages — what the budget is
    /// enforced against (quantized pages count their packed size).
    pub fn kv_resident_bytes(&self) -> usize {
        self.engine.kv_resident_bytes()
    }

    /// Sequences currently paged out to the spill tier.
    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    /// Extract the request with wire id `ticket` for migration to another
    /// cartridge: the request plus — once it has started decoding — a
    /// [`DecodeCheckpoint`] whose leading `keep_prefix` prompt tokens are
    /// exported by reference (the caller probed the target's radix cache
    /// first; pass 0 for a fully by-value export). Still-queued requests —
    /// and admitted requests still mid-prefill, which have no sampled token
    /// yet — come back without a checkpoint: there is no decode state to
    /// move, and the target's own prefix cache absorbs whatever prompt
    /// prefix it already holds.
    /// Returns `None` when the ticket is unknown or already completed.
    /// The request leaves this scheduler entirely; its KV pages are freed.
    pub fn export(
        &mut self,
        ticket: u64,
        keep_prefix: usize,
    ) -> Option<(GenRequest, Option<DecodeCheckpoint>)> {
        if let Some(i) = self.queue.iter().position(|e| e.id() == ticket) {
            return match self.queue.remove(i) {
                Some(QueueEntry::Fresh(req, _)) => Some((req, None)),
                Some(QueueEntry::Resume(req, ckpt, _)) => Some((req, Some(*ckpt))),
                None => None,
            };
        }
        if let Some(i) = self.spilled.iter().position(|s| s.a.req.id == ticket) {
            // a spilled sequence migrates straight from the spill file —
            // its engine pages are already gone. The snapshot is by value
            // (keep_prefix is ignored: re-slicing rows for a by-ref export
            // is not worth the copy it would take here).
            let SpilledSeq { a, .. } = self.spilled.remove(i);
            let kv = match self.spill.as_mut().expect("spilled entries imply a spill tier")
                .restore(ticket)
            {
                Ok(kv) => kv,
                Err(e) => {
                    // checkpoint-free export: the target re-prefills
                    eprintln!(
                        "[ita-scheduler] export of spilled request {ticket} lost its KV \
                         ({e:#}); exporting checkpoint-free"
                    );
                    return Some((a.req, None));
                }
            };
            self.metrics.migrated_out += 1;
            if self.trace.enabled() {
                let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Export);
                ev.req = ticket;
                ev.a = kv.value_rows() as u64;
                self.trace.record(ev);
            }
            let ckpt = DecodeCheckpoint {
                prompt: a.prompt,
                generated: a.generated,
                kv,
                spec_proposed: a.spec_proposed,
                spec_accepted: a.spec_accepted,
            };
            return Some((a.req, Some(ckpt)));
        }
        let i = self.active.iter().position(|a| a.req.id == ticket)?;
        // stable removal: `active` stays in admission order (see harvest)
        let a = self.active.remove(i);
        // in-flight draft state is transient (verified-or-rolled-back
        // within each step), so exports between steps just drop the
        // sequence's draft shadow — the checkpoint never carries it
        if let Some(spec) = self.spec.as_mut() {
            spec.drop_seq(a.seq);
        }
        if a.generated.is_empty() {
            // still prefilling: the partial KV is freed and the request
            // restarts cleanly elsewhere (byte-identical outputs either
            // way — prefill is deterministic in absolute position)
            self.engine.free_sequence(a.seq);
            if self.trace.enabled() {
                let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Export);
                ev.req = a.req.id;
                self.trace.record(ev);
            }
            return Some((a.req, None));
        }
        let by_ref = keep_prefix
            .min(a.prompt.len().saturating_sub(1))
            .min(self.engine.seq_len(a.seq));
        let kv = self
            .engine
            .snapshot_seq(a.seq, by_ref)
            .expect("active sequences snapshot cleanly");
        self.engine.free_sequence(a.seq);
        self.metrics.migrated_out += 1;
        if self.trace.enabled() {
            let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Export);
            ev.req = a.req.id;
            ev.a = kv.value_rows() as u64;
            ev.b = kv.by_ref_len as u64;
            self.trace.record(ev);
        }
        let ckpt = DecodeCheckpoint {
            prompt: a.prompt,
            generated: a.generated,
            kv,
            spec_proposed: a.spec_proposed,
            spec_accepted: a.spec_accepted,
        };
        Some((a.req, Some(ckpt)))
    }

    /// First-class preemption: remove the request with wire id `ticket`
    /// from the queue or the active set, free its KV pages, and return a
    /// partial [`GenResult`] ([`FinishReason::Cancelled`]) holding whatever
    /// output was committed before the cancel landed. `None` when the
    /// ticket is unknown or already completed — callers treat that as a
    /// benign race with completion.
    ///
    /// Cancellation is the eviction half of [`export`](Self::export) minus
    /// the checkpoint: the sequence's KV pages and draft shadow are
    /// dropped, surviving requests are untouched, and the freed slot
    /// admits queued work on the next step.
    pub fn cancel(&mut self, ticket: u64) -> Option<GenResult> {
        let now = Instant::now();
        if let Some(i) = self.queue.iter().position(|e| e.id() == ticket) {
            let (req, prompt_tokens, generated, sp, sa, enq) = match self.queue.remove(i) {
                Some(QueueEntry::Fresh(req, enq)) => {
                    let n = self.tokenizer.encode(&req.prompt).len();
                    (req, n, Vec::new(), 0, 0, enq)
                }
                // a queued checkpoint holds its KV by value — dropping the
                // entry is the whole eviction
                Some(QueueEntry::Resume(req, ckpt, enq)) => {
                    let n = ckpt.prompt.len();
                    (req, n, ckpt.generated, ckpt.spec_proposed, ckpt.spec_accepted, enq)
                }
                None => return None,
            };
            self.metrics.preempted_requests += 1;
            if self.trace.enabled() {
                let mut ev = TraceEvent::at(self.trace.ts_us(now), TraceKind::Preempt);
                ev.req = req.id;
                ev.a = generated.len() as u64;
                self.trace.record(ev);
            }
            let total = now.duration_since(enq).as_secs_f64();
            return Some(GenResult {
                id: req.id,
                prompt_tokens,
                skipped_prompt_tokens: 0,
                text: self.tokenizer.decode(&generated),
                tokens: generated,
                spec_proposed: sp,
                spec_accepted: sa,
                ttft_s: 0.0,
                itl_s: 0.0,
                total_s: total,
                finish: FinishReason::Cancelled,
            });
        }
        if let Some(i) = self.spilled.iter().position(|s| s.a.req.id == ticket) {
            // a spilled victim's pages live only in the spill file: drop
            // the region without reading it back
            let SpilledSeq { a, .. } = self.spilled.remove(i);
            self.spill.as_mut().expect("spilled entries imply a spill tier").discard(ticket);
            self.metrics.preempted_requests += 1;
            if self.trace.enabled() {
                let mut ev = TraceEvent::at(self.trace.ts_us(now), TraceKind::Preempt);
                ev.req = ticket;
                ev.a = a.generated.len() as u64;
                self.trace.record(ev);
            }
            return Some(GenResult {
                id: a.req.id,
                prompt_tokens: a.prompt.len(),
                skipped_prompt_tokens: a.skipped,
                text: self.tokenizer.decode(&a.generated),
                tokens: a.generated,
                spec_proposed: a.spec_proposed,
                spec_accepted: a.spec_accepted,
                ttft_s: a
                    .first_token_at
                    .map(|t| t.duration_since(a.enqueued).as_secs_f64())
                    .unwrap_or(0.0),
                itl_s: 0.0,
                total_s: now.duration_since(a.enqueued).as_secs_f64(),
                finish: FinishReason::Cancelled,
            });
        }
        let i = self.active.iter().position(|a| a.req.id == ticket)?;
        // stable removal, as everywhere else: admission order is preserved
        let a = self.active.remove(i);
        if let Some(spec) = self.spec.as_mut() {
            spec.drop_seq(a.seq);
        }
        let kv_rows = self.engine.seq_len(a.seq) as u64;
        self.engine.free_sequence(a.seq);
        self.metrics.preempted_requests += 1;
        if self.trace.enabled() {
            let mut ev = TraceEvent::at(self.trace.ts_us(now), TraceKind::Preempt);
            ev.req = a.req.id;
            ev.a = a.generated.len() as u64;
            ev.b = kv_rows;
            self.trace.record(ev);
        }
        Some(GenResult {
            id: a.req.id,
            prompt_tokens: a.prompt.len(),
            skipped_prompt_tokens: a.skipped,
            text: self.tokenizer.decode(&a.generated),
            tokens: a.generated,
            spec_proposed: a.spec_proposed,
            spec_accepted: a.spec_accepted,
            ttft_s: a
                .first_token_at
                .map(|t| t.duration_since(a.enqueued).as_secs_f64())
                .unwrap_or(0.0),
            itl_s: 0.0,
            total_s: now.duration_since(a.enqueued).as_secs_f64(),
            finish: FinishReason::Cancelled,
        })
    }

    /// Replace the prefill chunk budget for subsequent steps — the
    /// adaptive-prefill controller's knob (0 = run-to-completion prefill).
    /// Takes effect at the next step's row composition; in-flight chunks
    /// are unaffected.
    pub fn set_prefill_chunk(&mut self, n: usize) {
        self.opts.prefill_chunk_tokens = n;
    }

    /// Current prefill chunk budget (tokens per step; 0 = unchunked).
    pub fn prefill_chunk_tokens(&self) -> usize {
        self.opts.prefill_chunk_tokens
    }

    /// Drain the tokens committed since the last drain, per wire ticket.
    /// Always empty unless [`SchedulerOpts::stream_tokens`] is on. The
    /// worker drains after every step and forwards the batches to the
    /// dispatcher, which fans them out to per-request token streams.
    pub fn take_streamed(&mut self) -> Vec<(u64, Vec<u32>)> {
        std::mem::take(&mut self.streamed)
    }

    /// Periodic decode-checkpoint updates for every request that has
    /// started decoding, keyed by wire id (mid-prefill requests have no
    /// decode state and are skipped). The worker piggybacks these on its
    /// periodic metric checkpoints, so if this cartridge later panics the
    /// dispatcher resumes each request from its last checkpointed decode
    /// step instead of prefill.
    ///
    /// The first update per request ships a full [`KvSnapshot`]; steady-
    /// state updates ship only the rows appended since the previous one as
    /// a [`KvSnapshotDelta`] naming that checkpoint's chain id — so the
    /// per-interval checkpoint cost is O(tokens decoded this interval),
    /// not O(context). Per-ticket channel FIFO ordering makes the chain
    /// reliable; a receiver that loses the chain drops its stored
    /// checkpoint and the *next* call here re-ships a full snapshot only
    /// if this scheduler also lost its state (requeue) — the normal
    /// degradation is re-prefill, exactly the pre-delta behaviour.
    ///
    /// Sequences currently in the spill tier are skipped: spill is
    /// lossless, their chain state is retained, and the delta chain simply
    /// resumes after the restore.
    ///
    /// [`KvSnapshot`]: crate::host::kv_cache::KvSnapshot
    pub fn decode_checkpoints(&mut self) -> Vec<(u64, CheckpointUpdate)> {
        let mut out = Vec::new();
        for a in &mut self.active {
            if a.generated.is_empty() {
                continue;
            }
            let committed = self.engine.seq_len(a.seq);
            self.next_ckpt_id += 1;
            let id = self.next_ckpt_id;
            let kv = if a.ckpt_id == 0 {
                let snap = self
                    .engine
                    .snapshot_seq(a.seq, 0)
                    .expect("active sequences snapshot cleanly");
                self.metrics.ckpt_full_bytes += snap.wire_bytes() as u64;
                a.ckpt_id = id;
                a.ckpt_len = snap.len;
                KvCheckpoint::Full { id, snap }
            } else {
                // rows appended since the last checkpoint travel by value;
                // the `by_ref_len` header names the retained base rows
                // (min() is defensive — commits are monotone between
                // checkpoints, rollbacks resolve within a step)
                let from = a.ckpt_len.min(committed);
                let rows = self
                    .engine
                    .snapshot_seq(a.seq, from)
                    .expect("active sequences snapshot cleanly");
                let delta = KvSnapshotDelta { base_id: a.ckpt_id, id, rows };
                self.metrics.ckpt_delta_bytes += delta.wire_bytes() as u64;
                a.ckpt_id = id;
                a.ckpt_len = delta.rows.len;
                KvCheckpoint::Delta(delta)
            };
            out.push((
                a.req.id,
                CheckpointUpdate {
                    prompt: a.prompt.clone(),
                    generated: a.generated.clone(),
                    kv,
                    spec_proposed: a.spec_proposed,
                    spec_accepted: a.spec_accepted,
                },
            ));
        }
        out
    }

    /// Longest prefix of `prompt` this cartridge's radix cache holds right
    /// now — the migration probe (the dispatcher cannot see engine state
    /// directly; it asks over the worker channel).
    pub fn cached_prefix_tokens(&self, prompt: &str) -> usize {
        self.engine.cached_prefix_len(&self.tokenizer.encode(prompt))
    }

    /// Live per-request by-value KV export sizes, in serialized wire bytes
    /// ([`KvSnapshot::wire_bytes`](crate::host::kv_cache::KvSnapshot::wire_bytes)),
    /// keyed by wire id — the dispatcher's migration-cost **re-probe**. A
    /// periodic checkpoint's size is up to one checkpoint interval stale
    /// (a long decode grows a page every 16 tokens); this is exact as of
    /// the last committed step, computed from the sequence length alone
    /// (no KV is copied). Mid-prefill and still-queued fresh requests
    /// report 0 — their export ships no KV at all; queued resume entries
    /// report their checkpoint's size.
    pub fn live_kv_bytes(&self) -> Vec<(u64, usize)> {
        let dims = self.engine.dims();
        let queued = self.queue.iter().map(|e| match e {
            QueueEntry::Fresh(req, _) => (req.id, 0),
            QueueEntry::Resume(req, ckpt, _) => (req.id, ckpt.kv.wire_bytes()),
        });
        let active = self.active.iter().map(move |a| {
            let bytes = if a.generated.is_empty() {
                0 // still prefilling: exports travel checkpoint-free
            } else {
                crate::host::kv_cache::KvSnapshot::wire_bytes_for(
                    dims.n_layers,
                    dims.d_model,
                    self.engine.seq_len(a.seq),
                )
            };
            (a.req.id, bytes)
        });
        // spilled sequences export exactly the snapshot already on disk
        let spilled = self.spilled.iter().map(|s| (s.a.req.id, s.bytes));
        queued.chain(active).chain(spilled).collect()
    }

    /// Radix-cache occupancy for checkpoint piggybacking (`None` when the
    /// prefix cache is disabled — the dispatcher then never prunes).
    pub fn prefix_occupancy(&self) -> Option<Vec<Vec<u32>>> {
        self.engine.prefix_cache().map(|pc| pc.cached_prefixes())
    }

    fn finish(&mut self, a: Active, now: Instant) -> GenResult {
        self.engine.free_sequence(a.seq);
        if let Some(spec) = self.spec.as_mut() {
            spec.drop_seq(a.seq);
        }
        self.metrics.requests_completed += 1;
        let total = now.duration_since(a.enqueued).as_secs_f64();
        let decode_time = a
            .first_token_at
            .map(|t| now.duration_since(t).as_secs_f64())
            .unwrap_or(0.0);
        // intervals decoded HERE: a fresh request spans len-1 intervals
        // from its first token; a resumed one spans one interval per token
        // decoded since the restore (inherited tokens cost nothing here)
        let intervals = a.generated.len().saturating_sub(a.resumed_len.max(1));
        let itl = if intervals > 0 { decode_time / intervals as f64 } else { 0.0 };
        self.metrics.itl.record(itl);
        if self.trace.enabled() {
            // lifecycle spans: Queued [enqueue → admit] + Active [admit →
            // complete] tile the request's E2E latency, so their durations
            // sum to the Complete event's reported total within rounding
            // (the `trace_check` schema checker pins a 3 µs tolerance)
            let enq = self.trace.ts_us(a.enqueued);
            let adm = self.trace.ts_us(a.admitted);
            let end = self.trace.ts_us(now);
            let rid = a.req.id;
            let toks = a.generated.len() as u64;
            let mut q = TraceEvent::at(enq, TraceKind::Queued);
            q.dur_us = adm.saturating_sub(enq);
            q.req = rid;
            self.trace.record(q);
            let mut act = TraceEvent::at(adm, TraceKind::Active);
            act.dur_us = end.saturating_sub(adm);
            act.req = rid;
            act.a = toks;
            self.trace.record(act);
            let mut c = TraceEvent::at(end, TraceKind::Complete);
            c.req = rid;
            c.a = toks;
            c.b = (total * 1e6).round() as u64;
            self.trace.record(c);
        }
        let finish = if a.req.stop_at_eos && a.generated.last() == Some(&EOS) {
            FinishReason::Eos
        } else {
            FinishReason::MaxTokens
        };
        GenResult {
            id: a.req.id,
            prompt_tokens: a.prompt.len(),
            skipped_prompt_tokens: a.skipped,
            text: self.tokenizer.decode(&a.generated),
            tokens: a.generated,
            spec_proposed: a.spec_proposed,
            spec_accepted: a.spec_accepted,
            ttft_s: a
                .first_token_at
                .map(|t| t.duration_since(a.enqueued).as_secs_f64())
                .unwrap_or(0.0),
            itl_s: itl,
            total_s: total,
            finish,
        }
    }

    /// Metrics snapshot (wall clock up to now).
    pub fn metrics(&self) -> ServingMetrics {
        let mut m = self.metrics.clone();
        self.finish_snapshot(&mut m);
        m
    }

    /// Metrics snapshot with the per-sample latency recorders left empty —
    /// the checkpoint path. The recorders grow one sample per completion
    /// (`ttft`/`itl`) or per decoded token (`itl_step`), so cloning them
    /// into every periodic checkpoint would make total checkpoint cost
    /// quadratic in work served; counters and ledgers are O(1).
    pub fn counter_metrics(&self) -> ServingMetrics {
        let mut m = self.metrics.clone_counters();
        self.finish_snapshot(&mut m);
        m
    }

    fn finish_snapshot(&self, m: &mut ServingMetrics) {
        m.wall_s = self.started.elapsed().as_secs_f64();
        m.batch_waste = self.batch_stats.waste();
        m.mixed_waves = self.batch_stats.mixed_waves;
        m.pipeline_stages = self.engine.n_stages() as u64;
        let link = self.engine.link_stats();
        m.link_hops = link.hops;
        m.link_bytes = link.bytes;
        m.link_time_s = link.modeled_time_s;
        m.stage_slots = self.batch_stats.stage_slots;
        m.stage_busy_slots = self.batch_stats.busy_stage_slots;
        m.traffic = self.engine.traffic();
        m.interface_bytes = m.traffic.total();
        let macs = self.engine.device_stats().macs;
        m.device_macs = macs;
        // modeled energy covers the target AND draft engines' MAC work at
        // the ITA operating point; `device_macs` stays target-only so the
        // established counter keeps its meaning
        let draft_macs = self.spec.as_ref().map_or(0, |s| s.device_macs());
        m.energy_j = (macs + draft_macs) as f64 * self.pj_per_mac * 1e-12;
        let (quantized, materialized) = self.engine.kv_quant_stats();
        m.kv_pages_quantized = quantized;
        m.kv_pages_materialized = materialized;
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// True when request-lifecycle tracing is on
    /// ([`SchedulerOpts::trace_capacity`] > 0).
    pub fn trace_enabled(&self) -> bool {
        self.trace.enabled()
    }

    /// Drain every event recorded since the last drain — the worker
    /// piggybacks these on its periodic checkpoints.
    pub fn take_trace_events(&mut self) -> Vec<TraceEvent> {
        self.trace.drain()
    }

    /// Return and reset the count of events lost to ring overflow.
    pub fn take_trace_dropped(&mut self) -> u64 {
        self.trace.take_dropped()
    }

    /// Stamp a periodic-checkpoint instant on the trace (`n` = decode
    /// checkpoints carried in the report).
    pub fn note_checkpoint(&mut self, n: usize) {
        if self.trace.enabled() {
            let mut ev = TraceEvent::at(self.trace.now_us(), TraceKind::Checkpoint);
            ev.a = n as u64;
            self.trace.record(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::sim::SimDevice;
    use crate::host::embedding::EmbeddingTable;

    fn scheduler(seed: u64) -> Option<Scheduler> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("MANIFEST.txt").exists() {
            eprintln!("skipping: artifacts/tiny not built");
            return None;
        }
        let (m, s) = crate::runtime::weights::load_artifacts(&dir).unwrap();
        let dev = SimDevice::load(&m, &s).unwrap();
        let emb = EmbeddingTable::new(dev.weights().emb.clone());
        let n_heads = m.n_heads;
        let engine = Engine::new(Box::new(dev), emb, n_heads);
        Some(Scheduler::new(engine, SchedulerOpts { seed, ..SchedulerOpts::default() }))
    }

    #[test]
    fn synthetic_scheduler_completes_without_artifacts() {
        let engine = Engine::synthetic(&crate::config::ModelConfig::TINY, 3);
        let mut s = Scheduler::new(engine, SchedulerOpts::default());
        for i in 0..5 {
            s.submit(GenRequest::greedy(i, "clean checkout", 6));
        }
        let r = s.run_to_completion().unwrap();
        assert_eq!(r.len(), 5);
        let m = s.metrics();
        assert_eq!(m.requests_completed, 5);
        assert_eq!(m.interface_bytes, m.traffic.total());
        assert!(m.traffic.protocol_total() > 0);
    }

    #[test]
    fn chunked_prefill_interleaves_decode_rows() {
        // one sequence decoding, one long prompt prefilling: every
        // iteration must advance the decode by exactly one token while the
        // prefill proceeds chunk by chunk
        let opts = SchedulerOpts { prefill_chunk_tokens: 8, ..SchedulerOpts::default() };
        let mut s = Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 11), opts);
        let mut warm = GenRequest::greedy(0, "steady decode stream", 64);
        warm.stop_at_eos = false;
        s.submit(warm);
        // "steady decode stream" = 21 tokens (BOS + 20 bytes): chunks of
        // 8+8+5, then decode
        for _ in 0..4 {
            s.step().unwrap();
        }
        let before = s.metrics();
        assert_eq!(before.ttft.count(), 1, "warm stream should be decoding");
        let long_prompt = "long prompt ".repeat(40); // 481 tokens
        let mut long = GenRequest::greedy(1, &long_prompt, 4);
        long.stop_at_eos = false;
        s.submit(long);
        for _ in 0..5 {
            s.step().unwrap();
        }
        let m = s.metrics();
        // the warm stream advanced one token per iteration — the long
        // prefill (480/8 = 60 iterations of work) did not stall it
        assert_eq!(m.tokens_generated, before.tokens_generated + 5);
        // and the long request is still mid-prefill: no first token yet
        assert_eq!(m.ttft.count(), 1, "long prefill finished implausibly fast");
        assert!(m.mixed_waves > 0, "no mixed prefill+decode wave was issued");
        assert!(m.prefill_chunks >= 5);
        // drive to completion: both streams finish correctly
        let mut results = s.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].tokens.len(), 64);
        assert_eq!(results[1].tokens.len(), 4);
        let m = s.metrics();
        assert!(m.itl_step.count() > 0, "per-token gap histogram is empty");
    }

    #[test]
    fn chunk_budget_does_not_change_greedy_outputs() {
        let run = |chunk: usize| {
            let opts = SchedulerOpts { prefill_chunk_tokens: chunk, ..SchedulerOpts::default() };
            let mut s =
                Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 5), opts);
            for i in 0..4 {
                s.submit(GenRequest::greedy(
                    i,
                    &format!("a moderately long shared prompt, variant {i}"),
                    7,
                ));
            }
            let mut r = s.run_to_completion().unwrap();
            r.sort_by_key(|x| x.id);
            r.into_iter().map(|x| x.tokens).collect::<Vec<_>>()
        };
        let sequential = run(0);
        for chunk in [1, 5, 16, 1024] {
            assert_eq!(run(chunk), sequential, "chunk budget {chunk} changed outputs");
        }
    }

    #[test]
    fn speculative_scheduler_matches_vanilla_and_conserves_counters() {
        use crate::coordinator::spec::{CartridgeEngines, SpecOpts};
        let tiny = crate::config::ModelConfig::TINY;
        let reqs = |s: &mut Scheduler| {
            for i in 0..3 {
                let mut r = GenRequest::greedy(i, &format!("speculate about tensors {i}"), 24);
                r.stop_at_eos = false;
                s.submit(r);
            }
        };
        let mut vanilla = Scheduler::new(Engine::synthetic(&tiny, 21), SchedulerOpts::default());
        reqs(&mut vanilla);
        let mut want = vanilla.run_to_completion().unwrap();
        want.sort_by_key(|r| r.id);

        // a perfect draft (same weights) and an unrelated draft must both
        // be byte-identical to vanilla — acceptance only changes speed
        for draft_seed in [21u64, 999] {
            let engines = CartridgeEngines::with_draft(
                Engine::synthetic(&tiny, 21),
                Engine::synthetic(&tiny, draft_seed),
            );
            let opts = SchedulerOpts {
                spec: SpecOpts { depth: 4, adaptive: true },
                ..SchedulerOpts::default()
            };
            let mut s = Scheduler::with_engines(engines, opts);
            reqs(&mut s);
            let mut got = s.run_to_completion().unwrap();
            got.sort_by_key(|r| r.id);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.tokens, w.tokens, "draft seed {draft_seed} changed outputs");
            }
            let m = s.metrics();
            assert!(m.spec_proposed > 0, "no speculation happened");
            assert_eq!(
                m.spec_proposed,
                m.spec_accepted + m.spec_rollbacks,
                "draft-token conservation violated"
            );
            assert!(m.spec_accept.count() > 0, "acceptance histogram is empty");
            // per-request counters reconcile with the cartridge totals
            let (p, a): (u64, u64) = got
                .iter()
                .fold((0, 0), |(p, a), r| (p + r.spec_proposed, a + r.spec_accepted));
            assert_eq!(p, m.spec_proposed);
            assert_eq!(a, m.spec_accepted);
            if draft_seed == 21 {
                // identical weights agree on every greedy token
                assert_eq!(m.spec_rollbacks, 0, "perfect draft should never be rejected");
                assert!(m.spec_acceptance() > 0.99);
            }
            // no KV leaked on either engine
            assert_eq!(s.engine().cache_stats().2, 0);
        }
    }

    #[test]
    fn speculation_respects_eos_and_token_budget() {
        use crate::coordinator::spec::{CartridgeEngines, SpecOpts};
        let tiny = crate::config::ModelConfig::TINY;
        // stop_at_eos on and a tiny budget: a deep verify chain must clip
        // exactly where sequential decode stops
        let run = |spec: bool| {
            let engines = if spec {
                CartridgeEngines::with_draft(
                    Engine::synthetic(&tiny, 4),
                    Engine::synthetic(&tiny, 4),
                )
            } else {
                CartridgeEngines::from(Engine::synthetic(&tiny, 4))
            };
            let opts = SchedulerOpts {
                spec: SpecOpts { depth: 8, adaptive: false },
                ..SchedulerOpts::default()
            };
            let mut s = Scheduler::with_engines(engines, opts);
            for (i, max) in [(0u64, 1usize), (1, 2), (2, 3), (3, 64)] {
                s.submit(GenRequest::greedy(i, "clip me", max));
            }
            let mut r = s.run_to_completion().unwrap();
            r.sort_by_key(|x| x.id);
            r.into_iter().map(|x| (x.tokens, x.finish)).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false), "speculation changed stop behaviour");
    }

    #[test]
    fn non_greedy_requests_never_speculate() {
        use crate::coordinator::spec::{CartridgeEngines, SpecOpts};
        let tiny = crate::config::ModelConfig::TINY;
        let engines = CartridgeEngines::with_draft(
            Engine::synthetic(&tiny, 8),
            Engine::synthetic(&tiny, 8),
        );
        let opts = SchedulerOpts {
            spec: SpecOpts { depth: 4, adaptive: false },
            ..SchedulerOpts::default()
        };
        let mut s = Scheduler::with_engines(engines, opts);
        s.submit(GenRequest {
            id: 0,
            prompt: "stochastic".into(),
            max_new_tokens: 8,
            sampling: crate::host::sampling::SamplingParams::top_k(5, 0.8),
            stop_at_eos: false,
        });
        let r = s.run_to_completion().unwrap();
        assert_eq!(r[0].tokens.len(), 8);
        assert_eq!(r[0].spec_proposed, 0);
        assert_eq!(s.metrics().spec_proposed, 0, "stochastic request speculated");
    }

    #[test]
    fn live_kv_bytes_reports_exact_snapshot_sizes() {
        let opts = SchedulerOpts { prefill_chunk_tokens: 4, ..SchedulerOpts::default() };
        let mut s = Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 6), opts);
        let mut long = GenRequest::greedy(0, "a decoding request", 32);
        long.stop_at_eos = false;
        s.submit(long);
        s.submit(GenRequest::greedy(1, "a prompt still prefilling when probed", 4));
        for _ in 0..6 {
            s.step().unwrap();
        }
        let sizes: std::collections::HashMap<u64, usize> =
            s.live_kv_bytes().into_iter().collect();
        // request 0 is decoding: the report must equal the actual by-value
        // snapshot it would export right now
        let seq0 = s.active.iter().find(|a| a.req.id == 0).unwrap().seq;
        let snap = s.engine().snapshot_seq(seq0, 0).unwrap();
        assert_eq!(sizes[&0], snap.wire_bytes());
        assert!(sizes[&0] > 32);
        // request 1 is mid-prefill (chunk 4/38): it would export nothing
        let a1 = s.active.iter().find(|a| a.req.id == 1).unwrap();
        assert!(a1.generated.is_empty(), "request 1 finished prefill too fast");
        assert_eq!(sizes[&1], 0);
    }

    #[test]
    fn export_mid_prefill_restarts_cleanly() {
        // a request exported while still prefilling has no decode state:
        // the export carries no checkpoint, the partial KV is freed, and
        // the target serves it byte-identically from scratch
        let opts = SchedulerOpts { prefill_chunk_tokens: 4, ..SchedulerOpts::default() };
        let tiny = crate::config::ModelConfig::TINY;
        let req = GenRequest::greedy(0, "a prompt that is still prefilling", 6);

        let mut r = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        r.submit(req.clone());
        let want = r.run_to_completion().unwrap().remove(0);

        let mut a = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        a.submit(req.clone());
        a.step().unwrap(); // 4 of 34 prompt tokens prefilled
        let (req2, ckpt) = a.export(0, 0).unwrap();
        assert!(ckpt.is_none(), "mid-prefill export must not carry a checkpoint");
        assert_eq!(a.metrics().migrated_out, 0);
        // the partial sequence's pages were freed with it
        assert_eq!(a.engine().cache_stats().2, 0);

        let mut b = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        b.submit(req2);
        let got = b.run_to_completion().unwrap().remove(0);
        assert_eq!(got.tokens, want.tokens);
    }

    #[test]
    fn export_resume_mid_decode_is_deterministic() {
        let opts = SchedulerOpts::default();
        let req = GenRequest {
            id: 0,
            prompt: "migration differential".into(),
            max_new_tokens: 24,
            sampling: crate::host::sampling::SamplingParams::greedy(),
            stop_at_eos: false,
        };
        // reference: the same request served without ever moving
        let mut r = Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 7), opts);
        r.submit(req.clone());
        let want = r.run_to_completion().unwrap().remove(0);

        // decode a few steps, export, resume on a different scheduler whose
        // cache already holds unrelated traffic
        let mut a = Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 7), opts);
        a.submit(req.clone());
        for _ in 0..6 {
            a.step().unwrap();
        }
        let (req2, ckpt) = a.export(0, 0).unwrap();
        let ckpt = ckpt.expect("mid-decode export carries a checkpoint");
        assert!(ckpt.generated.len() > 1, "export was not mid-decode");
        assert_eq!(ckpt.kv.by_ref_len, 0);
        // the exported sequence's pages left with it (the prefix cache may
        // still hold refs, but no live sequence remains)
        assert_eq!(a.engine().cache_stats().2, 0);

        let mut b = Scheduler::new(Engine::synthetic(&crate::config::ModelConfig::TINY, 7), opts);
        b.submit(GenRequest::greedy(9, "unrelated warmup traffic", 4));
        b.run_to_completion().unwrap();
        b.submit_resume(req2, ckpt, Instant::now());
        let out = b.run_to_completion().unwrap();
        let got = out.iter().find(|x| x.id == 0).unwrap();
        assert_eq!(got.tokens, want.tokens, "migrated decode diverged");
        assert_eq!(got.skipped_prompt_tokens, got.prompt_tokens, "resume must not re-prefill");
        let m = b.metrics();
        assert_eq!(m.resumed_requests, 1);
        assert!(m.restored_tokens > 0);
        assert_eq!(a.metrics().migrated_out, 1);
    }

    #[test]
    fn export_by_ref_rides_the_target_prefix_cache() {
        let opts = SchedulerOpts::default();
        let tiny = crate::config::ModelConfig::TINY;
        let req = GenRequest {
            id: 0,
            prompt: "shared system prompt, migrated".into(),
            max_new_tokens: 16,
            sampling: crate::host::sampling::SamplingParams::greedy(),
            stop_at_eos: false,
        };
        let mut r = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        r.submit(req.clone());
        let want = r.run_to_completion().unwrap().remove(0);

        // the target has served the same prompt before: its radix cache
        // covers all but the last prompt token
        let mut b = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        b.submit(GenRequest::greedy(5, &req.prompt, 3));
        b.run_to_completion().unwrap();
        let keep = b.cached_prefix_tokens(&req.prompt);
        assert!(keep > 0, "target cache should hold the prompt");

        let mut a = Scheduler::new(Engine::synthetic(&tiny, 7), opts);
        a.submit(req.clone());
        for _ in 0..4 {
            a.step().unwrap();
        }
        let (req2, ckpt) = a.export(0, keep).unwrap();
        let ckpt = ckpt.expect("mid-decode export carries a checkpoint");
        // the promised prefix travelled by reference, not by value
        assert_eq!(ckpt.kv.by_ref_len, keep);
        assert!(ckpt.kv.value_rows() < ckpt.kv.len);
        b.submit_resume(req2, ckpt, Instant::now());
        let out = b.run_to_completion().unwrap();
        let got = out.iter().find(|x| x.id == 0).unwrap();
        assert_eq!(got.tokens, want.tokens, "by-ref migrated decode diverged");
        assert!(b.metrics().prefill_skipped_tokens >= keep as u64);
    }

    #[test]
    fn completes_all_requests() {
        let Some(mut s) = scheduler(1) else { return };
        for i in 0..7 {
            s.submit(GenRequest::greedy(i, "ab", 5));
        }
        let results = s.run_to_completion().unwrap();
        assert_eq!(results.len(), 7);
        for r in &results {
            assert!(r.tokens.len() <= 5);
            assert!(!r.tokens.is_empty());
        }
        let m = s.metrics();
        assert_eq!(m.requests_completed, 7);
        assert!(m.tokens_generated >= 7);
        // all KV pages returned
        let (_, free, live) = s.engine().cache_stats();
        assert_eq!(live, 0);
        assert!(free > 0);
    }

    #[test]
    fn greedy_output_independent_of_concurrency() {
        // the same request must produce the same tokens whether it is
        // served alone or alongside others (row-independence + greedy)
        let Some(mut solo) = scheduler(2) else { return };
        solo.submit(GenRequest::greedy(0, "hello", 8));
        let alone = &solo.run_to_completion().unwrap()[0].tokens.clone();

        let Some(mut busy) = scheduler(3) else { return };
        for i in 0..4 {
            busy.submit(GenRequest::greedy(i, if i == 0 { "hello" } else { "xyz" }, 8));
        }
        let results = busy.run_to_completion().unwrap();
        let same = results.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(&same.tokens, alone);
    }

    #[test]
    fn deterministic_across_runs_with_same_seed() {
        let run = |seed| -> Option<Vec<Vec<u32>>> {
            let mut s = scheduler(seed)?;
            for i in 0..3 {
                s.submit(GenRequest {
                    id: i,
                    prompt: "sample".into(),
                    max_new_tokens: 6,
                    sampling: crate::host::sampling::SamplingParams::top_k(5, 0.8),
                    stop_at_eos: false,
                });
            }
            let mut r = s.run_to_completion().unwrap();
            r.sort_by_key(|x| x.id);
            Some(r.into_iter().map(|x| x.tokens).collect())
        };
        let Some(a) = run(9) else { return };
        let b = run(9).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn respects_max_new_tokens() {
        let Some(mut s) = scheduler(4) else { return };
        s.submit(GenRequest::greedy(0, "q", 1));
        let r = s.run_to_completion().unwrap();
        assert_eq!(r[0].tokens.len(), 1);
        assert_eq!(r[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn metrics_have_latencies() {
        let Some(mut s) = scheduler(5) else { return };
        s.submit(GenRequest::greedy(0, "metrics", 4));
        s.run_to_completion().unwrap();
        let m = s.metrics();
        assert!(m.ttft.count() >= 1);
        assert!(m.wall_s > 0.0);
        assert!(m.interface_bytes > 0);
        assert!(m.device_macs > 0);
    }

    #[test]
    fn cancel_mid_decode_frees_kv_and_leaves_survivors_byte_identical() {
        let tiny = crate::config::ModelConfig::TINY;
        let opts = SchedulerOpts::default();
        // uncontended reference run for the surviving request
        let mut survivor = GenRequest::greedy(1, "the survivor", 12);
        survivor.stop_at_eos = false;
        let mut solo = Scheduler::new(Engine::synthetic(&tiny, 6), opts);
        solo.submit(survivor.clone());
        let want = solo.run_to_completion().unwrap().remove(0);

        let mut s = Scheduler::new(Engine::synthetic(&tiny, 6), opts);
        let mut victim = GenRequest::greedy(0, "cancel me please", 64);
        victim.stop_at_eos = false;
        s.submit(victim);
        s.submit(survivor);
        for _ in 0..4 {
            s.step().unwrap();
        }
        let partial = s.cancel(0).expect("victim is in flight");
        assert_eq!(partial.finish, FinishReason::Cancelled);
        assert!(!partial.tokens.is_empty(), "decode had started before the cancel");
        assert_eq!(s.metrics().preempted_requests, 1);
        // unknown / already-cancelled tickets are a benign no-op
        assert!(s.cancel(0).is_none());
        assert!(s.cancel(99).is_none());
        let got = s.run_to_completion().unwrap().remove(0);
        assert_eq!(got.tokens, want.tokens, "cancel disturbed a survivor");
        // every KV page came back, the victim's included
        assert_eq!(s.engine().cache_stats().2, 0);
    }

    #[test]
    fn cancel_while_queued_returns_empty_partial() {
        let tiny = crate::config::ModelConfig::TINY;
        let opts = SchedulerOpts { max_active: 1, ..SchedulerOpts::default() };
        let mut s = Scheduler::new(Engine::synthetic(&tiny, 6), opts);
        s.submit(GenRequest::greedy(0, "occupies the only slot", 8));
        s.submit(GenRequest::greedy(1, "never admitted", 8));
        s.step().unwrap(); // admits request 0 only
        let r = s.cancel(1).expect("request 1 is still queued");
        assert_eq!(r.finish, FinishReason::Cancelled);
        assert!(r.tokens.is_empty());
        assert!(r.prompt_tokens > 0);
        assert_eq!(s.run_to_completion().unwrap().len(), 1);
        assert_eq!(s.engine().cache_stats().2, 0);
    }

    #[test]
    fn streamed_tokens_concatenate_to_final_output() {
        let tiny = crate::config::ModelConfig::TINY;
        let opts = SchedulerOpts { stream_tokens: true, ..SchedulerOpts::default() };
        let mut s = Scheduler::new(Engine::synthetic(&tiny, 4), opts);
        s.submit(GenRequest::greedy(0, "stream me", 9));
        s.submit(GenRequest::greedy(1, "and me too", 7));
        let mut streamed: std::collections::HashMap<u64, Vec<u32>> = Default::default();
        let mut done = Vec::new();
        while s.pending() > 0 {
            done.extend(s.step().unwrap());
            for (id, toks) in s.take_streamed() {
                streamed.entry(id).or_default().extend(toks);
            }
        }
        assert_eq!(done.len(), 2);
        for r in &done {
            assert_eq!(streamed[&r.id], r.tokens, "stream diverged for request {}", r.id);
        }
        assert!(s.take_streamed().is_empty(), "drain must reset the buffer");
    }

    #[test]
    fn decode_checkpoints_chain_full_then_delta() {
        let tiny = crate::config::ModelConfig::TINY;
        let mut s = Scheduler::new(Engine::synthetic(&tiny, 12), SchedulerOpts::default());
        let mut r = GenRequest::greedy(0, "delta checkpoint chain", 32);
        r.stop_at_eos = false;
        s.submit(r);
        for _ in 0..4 {
            s.step().unwrap();
        }
        let mut ups = s.decode_checkpoints();
        assert_eq!(ups.len(), 1);
        let (ticket, up) = ups.remove(0);
        assert_eq!(ticket, 0);
        assert!(matches!(up.kv, KvCheckpoint::Full { .. }), "first update ships the snapshot");
        let full_bytes = up.kv.wire_bytes();
        let mut stored = up.fold(None).expect("full update always folds");
        for _ in 0..3 {
            s.step().unwrap();
        }
        let (_, up) = s.decode_checkpoints().remove(0);
        let KvCheckpoint::Delta(ref d) = up.kv else { panic!("second update must be a delta") };
        assert_eq!(d.base_id, stored.0, "delta must extend the stored chain");
        assert!(up.kv.wire_bytes() < full_bytes, "delta carries only the appended rows");
        stored = up.fold(Some(stored)).expect("chained delta folds");
        // the composed checkpoint equals the full snapshot taken right now
        let seq = s.active[0].seq;
        let want = s.engine().snapshot_seq(seq, 0).unwrap();
        assert_eq!(stored.1.kv, want, "base ∘ delta diverged from a full snapshot");
        assert_eq!(stored.1.generated, s.active[0].generated);
        // a delta arriving without its base breaks the chain: no fold
        for _ in 0..2 {
            s.step().unwrap();
        }
        let (_, up) = s.decode_checkpoints().remove(0);
        assert!(up.fold(None).is_none(), "orphan delta must not produce a checkpoint");
    }

    #[test]
    fn kv_budget_spills_and_restores_byte_identically() {
        let tiny = crate::config::ModelConfig::TINY;
        let reqs = |s: &mut Scheduler| {
            for i in 0..3 {
                let mut r = GenRequest::greedy(i, &format!("spill differential {i}"), 12);
                r.stop_at_eos = false;
                s.submit(r);
            }
        };
        let mut vanilla = Scheduler::new(Engine::synthetic(&tiny, 13), SchedulerOpts::default());
        reqs(&mut vanilla);
        let mut want = vanilla.run_to_completion().unwrap();
        want.sort_by_key(|r| r.id);

        // a 1-byte budget forces everything but the front sequence out
        let opts = SchedulerOpts {
            kv_mem: KvMemOpts { budget_bytes: 1, spill: true, ..KvMemOpts::default() },
            ..SchedulerOpts::default()
        };
        let mut s = Scheduler::new(Engine::synthetic(&tiny, 13), opts);
        reqs(&mut s);
        let mut got = s.run_to_completion().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.tokens, w.tokens, "spill round-trip changed outputs");
        }
        let m = s.metrics();
        assert!(m.kv_spills > 0, "a 1-byte budget must force spills");
        assert!(m.kv_unspills > 0, "spilled sequences must come back");
        assert!(m.kv_spill_bytes >= m.kv_unspill_bytes);
        assert_eq!(s.spilled_len(), 0, "nothing may be left in the spill tier");
        assert_eq!(s.engine().cache_stats().2, 0);
    }

    #[test]
    fn set_prefill_chunk_applies_to_subsequent_steps() {
        let tiny = crate::config::ModelConfig::TINY;
        let opts = SchedulerOpts { prefill_chunk_tokens: 4, ..SchedulerOpts::default() };
        let mut s = Scheduler::new(Engine::synthetic(&tiny, 6), opts);
        assert_eq!(s.prefill_chunk_tokens(), 4);
        s.submit(GenRequest::greedy(0, "a long prompt that prefills over several chunks", 2));
        s.step().unwrap(); // one 4-token chunk under the old budget
        assert_eq!(s.active[0].prefilled, 4);
        s.set_prefill_chunk(0); // unchunked: the rest runs in one wave
        s.step().unwrap();
        let a = &s.active[0];
        assert_eq!(a.prefilled, a.prompt.len());
    }
}
