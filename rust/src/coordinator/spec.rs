//! Draft-cartridge speculative decoding: propose with a small model,
//! verify with the big one, accept the agreeing prefix.
//!
//! ITA cartridges have fixed ROM-embedded weights, so a fleet is naturally
//! heterogeneous: a small *draft* cartridge and a large *target* cartridge
//! are just two ASICs (the paper's split-brain design; cf. Cambricon-LLM's
//! pairing of unequal compute tiles). The target's stateless dataflow makes
//! k-token verification nearly free — the k verify rows of one sequence
//! ride the same mixed waves chunked prefill already uses, and one weight
//! sweep of the (DRAM-streaming) device serves all of them.
//!
//! ## Protocol (per decoding sequence, per scheduling iteration)
//!
//! 1. **Propose.** The draft engine catches up to the canonical token
//!    stream (prompt ++ generated), then greedily proposes up to `k` tokens
//!    `d₁..d_k`.
//! 2. **Verify.** The target runs `k + 1` rows of the SAME sequence in one
//!    batched wave: the pending sampled token, then `d₁..d_k`. Row `j`'s
//!    logits are exactly what vanilla decode would have produced after
//!    consuming the first `j` draft tokens — prefill/decode determinism in
//!    absolute position, the same property chunked prefill and by-ref
//!    migration rest on.
//! 3. **Accept.** Walk the rows in order, greedily sampling each: accept
//!    draft tokens while the target agrees, then take the target's own
//!    token (the *correction* at the first disagreement, or the *bonus*
//!    after the last row when everything matched). The emitted chain is
//!    `tokenᵢ₊₁ = argmax(target logits after tokens ..ᵢ)` — **byte-identical
//!    to vanilla greedy by construction**, whatever the draft proposes.
//! 4. **Roll back.** KV rows the target committed for rejected draft tokens
//!    are discarded ([`PagedKvCache::truncate_seq`]) without disturbing
//!    shared/COW pages; the draft's own KV rolls back the same way.
//!
//! Speculation state is **transient**: it exists only inside one scheduler
//! step, so decode checkpoints, migration exports, and panic-recovery
//! resumes — which all run between steps — never see an in-flight draft.
//! A migrated sequence's draft context is rebuilt lazily by the next
//! catch-up.
//!
//! Only greedy requests speculate (stochastic sampling would need
//! distribution-preserving rejection sampling); others fall back to plain
//! one-token decode rows transparently.
//!
//! [`PagedKvCache::truncate_seq`]: crate::host::kv_cache::PagedKvCache::truncate_seq

use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::engine::Engine;
use crate::host::kv_cache::SeqId;
use crate::host::sampling::{sample, SamplingParams};
use crate::util::prng::Prng;

/// Speculative-decoding configuration (carried by
/// [`SchedulerOpts`](super::scheduler::SchedulerOpts); active only when the
/// scheduler also holds a draft engine).
///
/// # Example
///
/// ```no_run
/// // (no_run: rustdoc test binaries miss the libxla rpath; the same flow
/// // is pinned by rust/tests/spec_decode_sim.rs)
/// use ita::config::ModelConfig;
/// use ita::coordinator::engine::Engine;
/// use ita::coordinator::request::GenRequest;
/// use ita::coordinator::scheduler::{Scheduler, SchedulerOpts};
/// use ita::coordinator::spec::{CartridgeEngines, SpecOpts};
///
/// // a big target cartridge paired with a small draft cartridge
/// let engines = CartridgeEngines::with_draft(
///     Engine::synthetic(&ModelConfig::TINY, 7),
///     Engine::synthetic(&ModelConfig::TINY, 7),
/// );
/// let opts = SchedulerOpts {
///     spec: SpecOpts { depth: 4, adaptive: true },
///     ..SchedulerOpts::default()
/// };
/// let mut sched = Scheduler::with_engines(engines, opts);
/// sched.submit(GenRequest::greedy(0, "hello ita", 16));
/// let results = sched.run_to_completion().unwrap();
/// // greedy outputs are byte-identical to a draft-less run
/// assert_eq!(results.len(), 1);
/// let m = sched.metrics();
/// assert_eq!(m.spec_proposed, m.spec_accepted + m.spec_rollbacks);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecOpts {
    /// Maximum draft tokens proposed per sequence per iteration (the `k`
    /// of classic speculative decoding). 0 disables speculation even when
    /// a draft engine is attached.
    pub depth: usize,
    /// Tune the per-sequence depth from its rolling acceptance rate: a
    /// sequence the draft predicts well climbs toward `depth`, one it
    /// predicts badly falls toward 1, so hopeless drafts stop wasting
    /// draft-engine work. `false` pins every sequence at `depth`.
    pub adaptive: bool,
}

impl Default for SpecOpts {
    fn default() -> Self {
        SpecOpts { depth: 4, adaptive: true }
    }
}

/// The engines one cartridge worker owns: the serving (target) engine,
/// optionally paired with a smaller draft engine for speculative decoding.
/// `From<Engine>` lets every existing draft-less call site keep passing a
/// bare [`Engine`].
pub struct CartridgeEngines {
    pub target: Engine,
    pub draft: Option<Engine>,
}

impl CartridgeEngines {
    /// Pair a target cartridge with a draft cartridge. The draft must share
    /// the target's vocabulary (it proposes token ids the target verifies);
    /// every other dimension — layers, width, FFN — is free, and smaller is
    /// the point.
    pub fn with_draft(target: Engine, draft: Engine) -> CartridgeEngines {
        CartridgeEngines { target, draft: Some(draft) }
    }
}

impl From<Engine> for CartridgeEngines {
    fn from(target: Engine) -> CartridgeEngines {
        CartridgeEngines { target, draft: None }
    }
}

/// Per-sequence adaptive-depth controller: an exponentially weighted
/// rolling acceptance rate drives the proposal depth between 1 and the
/// configured maximum.
#[derive(Debug, Clone)]
pub struct DepthController {
    max_depth: usize,
    adaptive: bool,
    k: usize,
    /// EWMA of per-wave acceptance rate (accepted / proposed).
    rate: f64,
}

impl DepthController {
    pub fn new(opts: &SpecOpts) -> DepthController {
        DepthController {
            max_depth: opts.depth.max(1),
            adaptive: opts.adaptive,
            // adaptive sequences start mid-range and earn their depth
            k: if opts.adaptive { opts.depth.max(1).div_ceil(2) } else { opts.depth.max(1) },
            rate: 0.5,
        }
    }

    /// Draft tokens to propose next wave.
    pub fn depth(&self) -> usize {
        self.k
    }

    /// Rolling acceptance rate in [0, 1].
    pub fn acceptance(&self) -> f64 {
        self.rate
    }

    /// Fold in one verify wave's outcome.
    pub fn observe(&mut self, accepted: usize, proposed: usize) {
        if !self.adaptive || proposed == 0 {
            return;
        }
        let wave = accepted as f64 / proposed as f64;
        self.rate = 0.7 * self.rate + 0.3 * wave;
        if self.rate >= 0.75 {
            self.k = (self.k + 1).min(self.max_depth);
        } else if self.rate < 0.35 {
            self.k = self.k.saturating_sub(1).max(1);
        }
    }
}

struct DraftSeq {
    /// The shadow sequence in the DRAFT engine's KV cache.
    id: SeqId,
    ctrl: DepthController,
}

/// Outcome of one verify wave, as the scheduler reports it back to
/// [`SpecDecoder::observe`].
#[derive(Debug, Clone, Copy)]
pub struct VerifyOutcome {
    /// Canonical stream length (prompt + generated) BEFORE this wave.
    pub stream_len: usize,
    /// Tokens actually appended to the stream this wave (accepted draft
    /// tokens plus the correction/bonus token, after EOS / token-budget
    /// clipping); ≥ 1.
    pub applied: usize,
    /// Draft tokens accepted into the stream.
    pub accepted: usize,
    /// Draft tokens proposed.
    pub proposed: usize,
}

/// The draft side of speculative decoding: owns the draft [`Engine`] and a
/// shadow sequence (plus a [`DepthController`]) per target sequence.
pub struct SpecDecoder {
    draft: Engine,
    opts: SpecOpts,
    seqs: HashMap<SeqId, DraftSeq>,
    /// Greedy sampling never draws from it; [`sample`] just wants one.
    rng: Prng,
}

impl SpecDecoder {
    pub fn new(draft: Engine, opts: SpecOpts) -> SpecDecoder {
        SpecDecoder { draft, opts, seqs: HashMap::new(), rng: Prng::new(0x5bec) }
    }

    /// Draft vocabulary (must match the target's for proposals to be
    /// meaningful token ids).
    pub fn vocab(&self) -> usize {
        self.draft.dims().vocab
    }

    /// Current proposal depth for `seq` (before any wave: the configured
    /// start depth).
    pub fn depth(&self, seq: SeqId) -> usize {
        self.seqs.get(&seq).map_or_else(
            || DepthController::new(&self.opts).depth(),
            |s| s.ctrl.depth(),
        )
    }

    /// Propose up to `min(depth, cap)` draft tokens for the target sequence
    /// `seq`, whose canonical token stream is `prompt ++ generated` (the
    /// last element being the still-unconsumed sampled token).
    ///
    /// The draft's shadow sequence is created on first use and **catches
    /// up** to the stream first — consuming any tokens it has not seen yet
    /// in bucket-packed batches — so a sequence that just finished prefill,
    /// resumed from a migration checkpoint, or took a multi-token accept
    /// last wave is handled uniformly. Only the not-yet-consumed suffix is
    /// ever materialized (a handful of tokens in steady state, the whole
    /// prompt exactly once), so the per-iteration cost does not grow with
    /// context length. Returns at least one token.
    pub fn propose(
        &mut self,
        seq: SeqId,
        prompt: &[u32],
        generated: &[u32],
        cap: usize,
    ) -> Result<Vec<u32>> {
        let total = prompt.len() + generated.len();
        ensure!(total > 0, "propose on an empty stream");
        ensure!(cap >= 1, "propose with a zero token cap");
        if !self.seqs.contains_key(&seq) {
            let id = self.draft.new_sequence();
            self.seqs.insert(seq, DraftSeq { id, ctrl: DepthController::new(&self.opts) });
        }
        let (draft_id, k) = {
            let s = self.seqs.get(&seq).expect("inserted above");
            (s.id, s.ctrl.depth().min(cap).max(1))
        };
        // defensive: a shadow that somehow ran ahead of the canonical
        // stream (it cannot, between steps) is rolled back to it
        if self.draft.seq_len(draft_id) >= total {
            self.draft.truncate_sequence(draft_id, total - 1)?;
        }
        // catch up: consume every canonical token the shadow has not seen,
        // including the pending one — the last row's logits seed the chain
        let have = self.draft.seq_len(draft_id);
        let mut pending: Vec<u32> = Vec::with_capacity(total - have);
        if have < prompt.len() {
            pending.extend_from_slice(&prompt[have..]);
            pending.extend_from_slice(generated);
        } else {
            pending.extend_from_slice(&generated[have - prompt.len()..]);
        }
        let bucket = self.draft.max_batch();
        let mut last: Vec<f32> = Vec::new();
        for chunk in pending.chunks(bucket) {
            let logits = self.draft.verify_step(draft_id, chunk)?;
            let v = logits.cols;
            last = logits.data[(chunk.len() - 1) * v..chunk.len() * v].to_vec();
        }
        debug_assert!(!last.is_empty(), "catch-up always has >= 1 pending token");
        let greedy = SamplingParams::greedy();
        let mut out = Vec::with_capacity(k);
        let mut tok = sample(&last, &greedy, &mut self.rng);
        out.push(tok);
        while out.len() < k {
            let logits = self.draft.forward(&[draft_id], &[tok])?;
            tok = sample(&logits.data, &greedy, &mut self.rng);
            out.push(tok);
        }
        // shadow now holds stream.len() + k - 1 rows (the newest proposal
        // was sampled but not consumed) — observe() reconciles it with
        // whatever the target actually accepted
        Ok(out)
    }

    /// Reconcile the shadow sequence with a verify wave's outcome: roll its
    /// KV back to the longest prefix consistent with the new canonical
    /// stream and feed the result to the depth controller.
    pub fn observe(&mut self, seq: SeqId, outcome: VerifyOutcome) -> Result<()> {
        let Some(s) = self.seqs.get_mut(&seq) else { return Ok(()) };
        s.ctrl.observe(outcome.accepted, outcome.proposed);
        // the shadow consumed stream ++ d[0..proposed-1]; of those draft
        // tokens, only the accepted prefix matches the new stream — and it
        // must also stay one behind the stream's still-unconsumed tail
        let valid = outcome.stream_len
            + outcome.accepted.min(outcome.proposed.saturating_sub(1));
        let keep = valid
            .min(outcome.stream_len + outcome.applied.max(1) - 1)
            .min(self.draft.seq_len(s.id));
        self.draft.truncate_sequence(s.id, keep)
    }

    /// Rolling acceptance rate for `seq`, if it ever speculated.
    pub fn acceptance(&self, seq: SeqId) -> Option<f64> {
        self.seqs.get(&seq).map(|s| s.ctrl.acceptance())
    }

    /// Drop the shadow sequence of a finished / exported / requeued target
    /// sequence, freeing its draft-side KV pages. No-op when `seq` never
    /// speculated.
    pub fn drop_seq(&mut self, seq: SeqId) {
        if let Some(s) = self.seqs.remove(&seq) {
            self.draft.free_sequence(s.id);
        }
    }

    /// Draft-engine KV pool statistics (for leak checks in tests).
    pub fn draft_cache_stats(&self) -> (usize, usize, usize) {
        self.draft.cache_stats()
    }

    /// Total MACs the draft engine's device has executed — the speculation
    /// share of the cartridge's energy accounting
    /// ([`ServingMetrics::energy_j`](super::metrics::ServingMetrics::energy_j)).
    pub fn device_macs(&self) -> u64 {
        self.draft.device_stats().macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::host::tokenizer::ByteTokenizer;

    #[test]
    fn fixed_depth_controller_never_moves() {
        let mut c = DepthController::new(&SpecOpts { depth: 6, adaptive: false });
        assert_eq!(c.depth(), 6);
        for _ in 0..20 {
            c.observe(0, 6);
        }
        assert_eq!(c.depth(), 6, "non-adaptive depth must stay pinned");
    }

    #[test]
    fn adaptive_depth_climbs_on_acceptance_and_falls_on_rejection() {
        let opts = SpecOpts { depth: 8, adaptive: true };
        let mut c = DepthController::new(&opts);
        let start = c.depth();
        assert!((1..=8).contains(&start));
        for _ in 0..30 {
            let k = c.depth();
            c.observe(k, k); // perfect draft
        }
        assert_eq!(c.depth(), 8, "perfect acceptance should reach max depth");
        for _ in 0..30 {
            let k = c.depth();
            c.observe(0, k); // hopeless draft
        }
        assert_eq!(c.depth(), 1, "zero acceptance should bottom out at 1");
        // and it never leaves [1, max]
        for i in 0..50 {
            c.observe(i % 2, 1);
            assert!((1..=8).contains(&c.depth()));
        }
    }

    #[test]
    fn propose_catches_up_and_proposes_greedy_draft_chain() {
        // the draft's proposals must equal what greedily decoding the draft
        // model itself would produce — pinned against a bare engine
        let cfg = ModelConfig::TINY;
        let toks = ByteTokenizer::new().encode("speculate");
        let mut spec = SpecDecoder::new(
            Engine::synthetic(&cfg, 3),
            SpecOpts { depth: 4, adaptive: false },
        );
        let d = spec.propose(SeqId(7), &toks, &[], 16).unwrap();
        assert_eq!(d.len(), 4);

        let mut reference = Engine::synthetic(&cfg, 3);
        let s = reference.new_sequence();
        let mut rng = Prng::new(0);
        let greedy = SamplingParams::greedy();
        let mut row = reference.prefill(s, &toks).unwrap();
        let mut want = Vec::new();
        for i in 0..4 {
            let t = sample(&row, &greedy, &mut rng);
            want.push(t);
            if i < 3 {
                // the newest proposal is sampled but not consumed — keep
                // the reference's committed length equal to the shadow's
                row = reference.forward(&[s], &[t]).unwrap().data;
            }
        }
        assert_eq!(d, want, "draft chain diverged from plain greedy decode");

        // a fully-accepted wave leaves the shadow one row behind the new
        // stream; the next propose consumes the gap and stays consistent
        let mut stream = toks.clone();
        stream.extend_from_slice(&d);
        stream.push(want[3].wrapping_add(1) % 258); // bonus token
        spec.observe(
            SeqId(7),
            VerifyOutcome { stream_len: toks.len(), applied: 5, accepted: 4, proposed: 4 },
        )
        .unwrap();
        // the stream splits anywhere: pass the original prompt and the new
        // tokens as `generated`, exercising the cross-boundary catch-up
        let d2 = spec.propose(SeqId(7), &toks, &stream[toks.len()..], 16).unwrap();
        assert_eq!(d2.len(), 4);
        // reference: feed the same gap tokens (the last proposal and the
        // bonus, which the shadow never consumed), then decode greedily
        let gap = reference
            .forward(&[s, s], &[stream[stream.len() - 2], stream[stream.len() - 1]])
            .unwrap();
        let v = gap.cols;
        let mut row = gap.data[v..2 * v].to_vec();
        let mut want2 = Vec::new();
        for i in 0..4 {
            let t = sample(&row, &greedy, &mut rng);
            want2.push(t);
            if i < 3 {
                row = reference.forward(&[s], &[t]).unwrap().data;
            }
        }
        assert_eq!(d2, want2, "post-accept catch-up diverged");
    }

    #[test]
    fn rejection_rolls_the_shadow_back_to_the_accepted_prefix() {
        let cfg = ModelConfig::TINY;
        let toks = ByteTokenizer::new().encode("reject me");
        let mut spec = SpecDecoder::new(
            Engine::synthetic(&cfg, 9),
            SpecOpts { depth: 4, adaptive: false },
        );
        let d = spec.propose(SeqId(1), &toks, &[], 16).unwrap();
        assert_eq!(d.len(), 4);
        // target rejected everything: applied = 1 correction token
        spec.observe(
            SeqId(1),
            VerifyOutcome { stream_len: toks.len(), applied: 1, accepted: 0, proposed: 4 },
        )
        .unwrap();
        // shadow rolled back to stream_len (it had consumed 3 draft tokens)
        let correction = [42u32];
        let d2 = spec.propose(SeqId(1), &toks, &correction, 16).unwrap();
        assert_eq!(d2.len(), 4);
        // cross-check against a fresh decoder fed the same stream: the
        // rollback must leave no trace of the rejected tokens
        let mut fresh = SpecDecoder::new(
            Engine::synthetic(&cfg, 9),
            SpecOpts { depth: 4, adaptive: false },
        );
        let d3 = fresh.propose(SeqId(1), &toks, &correction, 16).unwrap();
        assert_eq!(d2, d3, "rolled-back shadow diverged from a fresh one");
    }

    #[test]
    fn drop_seq_frees_draft_pages() {
        let cfg = ModelConfig::TINY;
        let toks = ByteTokenizer::new().encode("ephemeral");
        let mut spec = SpecDecoder::new(Engine::synthetic(&cfg, 5), SpecOpts::default());
        spec.propose(SeqId(3), &toks, &[], 8).unwrap();
        let (_, _, live) = spec.draft_cache_stats();
        assert_eq!(live, 1);
        spec.drop_seq(SeqId(3));
        let (alloc, free, live) = spec.draft_cache_stats();
        assert_eq!(live, 0);
        assert_eq!(alloc, free, "draft pages must return to the pool");
        // dropping an unknown sequence is a no-op
        spec.drop_seq(SeqId(99));
    }
}
