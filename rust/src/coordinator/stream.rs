//! Per-request token streams and cancellation handles — the client half of
//! the front door ([`frontdoor`](super::frontdoor)).
//!
//! A [`TokenStream`] is fed by the dispatcher from per-step
//! [`WorkerEvent::Tokens`](super::worker::WorkerEvent::Tokens) batches and
//! terminates with exactly one [`StreamItem::End`] carrying the full
//! [`GenResult`]. Dropping an unfinished stream cancels the request — a
//! disconnected client must not keep burning decode waves — and an explicit
//! [`CancelHandle`] offers the same preemption without dropping the stream,
//! so the partial result can still be observed.
//!
//! The contract (ordering, replay-after-failover, cancellation guarantees)
//! is specified in `docs/serving-front-door.md`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::request::GenResult;

/// One item of a request's token stream.
#[derive(Debug)]
pub enum StreamItem {
    /// Tokens committed by one scheduler step, in generation order (never
    /// empty). Batching per step keeps channel traffic O(waves) rather
    /// than O(tokens).
    Tokens(Vec<u32>),
    /// Terminal item: the request's complete [`GenResult`]. `tokens`
    /// inside it is the authoritative full output — the concatenation of
    /// every prior [`StreamItem::Tokens`] equals it (exactly-once token
    /// delivery, including across cartridge failover). `finish` reports
    /// [`Cancelled`](super::request::FinishReason::Cancelled) when the
    /// request was preempted, [`Error`](super::request::FinishReason::Error)
    /// when the fleet lost every cartridge.
    End(Box<GenResult>),
}

struct CancelInner {
    fire: Box<dyn Fn() + Send + Sync>,
    fired: AtomicBool,
}

/// Idempotent, clonable cancellation handle for one in-flight request.
///
/// The first [`cancel`](CancelHandle::cancel) (from any clone — including
/// the implicit one when an unfinished [`TokenStream`] is dropped) asks the
/// fleet to preempt the request: its KV pages are freed and the stream ends
/// with a partial result marked
/// [`Cancelled`](super::request::FinishReason::Cancelled). Cancelling a
/// request that already completed is a benign no-op — the stream ends with
/// the finished result instead.
pub struct CancelHandle {
    inner: Arc<CancelInner>,
}

impl Clone for CancelHandle {
    fn clone(&self) -> CancelHandle {
        CancelHandle { inner: Arc::clone(&self.inner) }
    }
}

impl CancelHandle {
    pub(crate) fn new(fire: impl Fn() + Send + Sync + 'static) -> CancelHandle {
        CancelHandle {
            inner: Arc::new(CancelInner { fire: Box::new(fire), fired: AtomicBool::new(false) }),
        }
    }

    /// Request preemption. Only the first call (across all clones) sends
    /// anything; the rest are no-ops.
    pub fn cancel(&self) {
        if !self.inner.fired.swap(true, Ordering::SeqCst) {
            (self.inner.fire)();
        }
    }

    /// Whether any clone of this handle has fired.
    pub fn is_cancelled(&self) -> bool {
        self.inner.fired.load(Ordering::SeqCst)
    }
}

/// Receiving half of one request's token stream (see [`StreamItem`]).
///
/// Dropping the stream before its [`StreamItem::End`] arrived cancels the
/// request — disconnect IS cancellation, the serving contract's core
/// guarantee. Use [`wait`](TokenStream::wait) to drain to completion, or
/// [`recv`](TokenStream::recv)/[`try_recv`](TokenStream::try_recv) to
/// consume incrementally.
pub struct TokenStream {
    rx: Receiver<StreamItem>,
    cancel: CancelHandle,
    done: bool,
}

impl TokenStream {
    pub(crate) fn new(rx: Receiver<StreamItem>, cancel: CancelHandle) -> TokenStream {
        TokenStream { rx, cancel, done: false }
    }

    /// A cancellation handle for this request, usable from any thread
    /// (e.g. a timeout watchdog) while this stream keeps being consumed.
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }

    /// Block for the next item. Returns `None` after the terminal
    /// [`StreamItem::End`] was delivered, or if the fleet went away
    /// without ever finishing the request.
    pub fn recv(&mut self) -> Option<StreamItem> {
        if self.done {
            return None;
        }
        match self.rx.recv() {
            Ok(item) => {
                if matches!(item, StreamItem::End(_)) {
                    self.done = true;
                }
                Some(item)
            }
            Err(_) => {
                self.done = true;
                None
            }
        }
    }

    /// Non-blocking [`recv`](TokenStream::recv): `None` when no item is
    /// ready right now (or the stream is finished).
    pub fn try_recv(&mut self) -> Option<StreamItem> {
        if self.done {
            return None;
        }
        match self.rx.try_recv() {
            Ok(item) => {
                if matches!(item, StreamItem::End(_)) {
                    self.done = true;
                }
                Some(item)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.done = true;
                None
            }
        }
    }

    /// Drain the stream to completion and return the final result —
    /// equivalent to [`ResultHandle::wait`](super::fleet::ResultHandle::wait)
    /// for clients that don't care about incremental tokens.
    pub fn wait(mut self) -> Result<GenResult> {
        while let Some(item) = self.recv() {
            if let StreamItem::End(r) = item {
                return Ok(*r);
            }
        }
        Err(anyhow!("stream closed before the request completed"))
    }
}

impl Drop for TokenStream {
    fn drop(&mut self) {
        // disconnect IS cancellation: a stream dropped before End means
        // nobody is reading this request's tokens anymore
        if !self.done {
            self.cancel.cancel();
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc::channel;

    use super::*;

    fn counted_handle() -> (CancelHandle, Arc<AtomicUsize>) {
        let fires = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fires);
        let h = CancelHandle::new(move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        (h, fires)
    }

    #[test]
    fn cancel_fires_exactly_once_across_clones() {
        let (h, fires) = counted_handle();
        let h2 = h.clone();
        assert!(!h.is_cancelled());
        h.cancel();
        h2.cancel();
        h.cancel();
        assert_eq!(fires.load(Ordering::SeqCst), 1);
        assert!(h2.is_cancelled());
    }

    #[test]
    fn dropping_an_unfinished_stream_cancels() {
        let (h, fires) = counted_handle();
        let (_tx, rx) = channel();
        drop(TokenStream::new(rx, h));
        assert_eq!(fires.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn finished_stream_does_not_cancel_on_drop() {
        let (h, fires) = counted_handle();
        let (tx, rx) = channel();
        let mut s = TokenStream::new(rx, h);
        tx.send(StreamItem::Tokens(vec![1, 2])).unwrap();
        tx.send(StreamItem::End(Box::new(crate::coordinator::request::GenResult {
            id: 0,
            prompt_tokens: 1,
            skipped_prompt_tokens: 0,
            tokens: vec![1, 2],
            text: String::new(),
            spec_proposed: 0,
            spec_accepted: 0,
            ttft_s: 0.0,
            itl_s: 0.0,
            total_s: 0.0,
            finish: crate::coordinator::request::FinishReason::MaxTokens,
        })))
        .unwrap();
        assert!(matches!(s.recv(), Some(StreamItem::Tokens(t)) if t == vec![1, 2]));
        assert!(matches!(s.recv(), Some(StreamItem::End(_))));
        assert!(s.recv().is_none(), "stream is exhausted after End");
        drop(s);
        assert_eq!(fires.load(Ordering::SeqCst), 0, "completed stream must not cancel");
    }

    #[test]
    fn wait_returns_the_final_result() {
        let (h, fires) = counted_handle();
        let (tx, rx) = channel();
        let s = TokenStream::new(rx, h);
        tx.send(StreamItem::Tokens(vec![7])).unwrap();
        tx.send(StreamItem::End(Box::new(crate::coordinator::request::GenResult {
            id: 9,
            prompt_tokens: 1,
            skipped_prompt_tokens: 0,
            tokens: vec![7],
            text: String::new(),
            spec_proposed: 0,
            spec_accepted: 0,
            ttft_s: 0.0,
            itl_s: 0.0,
            total_s: 0.0,
            finish: crate::coordinator::request::FinishReason::Eos,
        })))
        .unwrap();
        let r = s.wait().unwrap();
        assert_eq!(r.id, 9);
        assert_eq!(fires.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn severed_channel_ends_the_stream_and_wait_errors() {
        let (h, _fires) = counted_handle();
        let (tx, rx) = channel::<StreamItem>();
        drop(tx);
        let mut s = TokenStream::new(rx, h);
        assert!(s.recv().is_none());
        let (h2, _fires2) = counted_handle();
        let (tx2, rx2) = channel::<StreamItem>();
        drop(tx2);
        assert!(TokenStream::new(rx2, h2).wait().is_err());
    }
}
