//! Serving metrics: latency histograms, throughput, traffic — per engine
//! ([`ServingMetrics`]), and per fleet with per-cartridge breakdowns
//! ([`FleetMetrics`] / [`CartridgeMetrics`]), plus the live per-tenant ×
//! class series and SLO alert postures maintained by
//! [`telemetry`](super::telemetry).

use super::engine::TrafficLedger;
use super::telemetry::{alerts_json, tenants_json, AlertSnapshot, AlertState, TenantClassMetrics};

/// Fixed-capacity latency recorder with percentile queries.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_s: Vec<f64>,
}

impl LatencyRecorder {
    /// Record one sample. Non-finite values (NaN/±inf — a poisoned clock
    /// delta) are dropped at the door so they can never reach the sort in
    /// [`percentile`](LatencyRecorder::percentile) or skew
    /// [`mean`](LatencyRecorder::mean).
    pub fn record(&mut self, seconds: f64) {
        if seconds.is_finite() {
            self.samples_s.push(seconds);
        }
    }

    /// Fold another recorder's samples in (fleet aggregation).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_s.extend_from_slice(&other.samples_s);
    }

    pub fn count(&self) -> usize {
        self.samples_s.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        self.samples_s.iter().sum::<f64>() / self.samples_s.len() as f64
    }

    /// Percentile; `p` is clamped to [0, 100] (p=110 used to index past
    /// the end and panic). Total order via `f64::total_cmp` — no
    /// `partial_cmp().unwrap()` to die on, though `record` already keeps
    /// non-finite samples out.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_s.clone();
        s.sort_by(f64::total_cmp);
        let p = p.clamp(0.0, 100.0);
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx]
    }
}

/// Fixed-footprint log-bucketed latency histogram. Unlike
/// [`LatencyRecorder`] (exact, but one stored sample per event), this is
/// for per-*token* signals that fire for the life of a cartridge: memory
/// and clone cost stay O(1) no matter how long the fleet serves. Bucket
/// `i` counts samples in `[2^i, 2^(i+1))` microseconds; percentiles are
/// bucket upper edges, so within 2× of the true sample — plenty to tell a
/// bounded chunked-prefill gap from a run-to-completion stall.
#[derive(Debug, Clone)]
pub struct GapHistogram {
    buckets: [u64; 64],
    count: u64,
}

impl Default for GapHistogram {
    fn default() -> Self {
        GapHistogram { buckets: [0; 64], count: 0 }
    }
}

impl GapHistogram {
    fn bucket(seconds: f64) -> usize {
        let us = (seconds * 1e6).max(1.0);
        (us.log2() as usize).min(63)
    }

    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket(seconds)] += 1;
        self.count += 1;
    }

    /// Fold another histogram in (fleet aggregation).
    pub fn merge(&mut self, other: &GapHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The samples recorded since `earlier` was snapshotted: per-bucket
    /// saturating subtraction. Histograms are cumulative for the life of a
    /// cartridge, so controllers that want *interval* percentiles (e.g. the
    /// adaptive-prefill loop reading recent `itl_step` latency) diff the
    /// current histogram against the copy they kept from the last tick.
    /// Saturating: if `earlier` is not actually a prefix of `self` (merged
    /// from different sources), buckets clamp at 0 instead of wrapping.
    pub fn diff(&self, earlier: &GapHistogram) -> GapHistogram {
        let mut out = GapHistogram::default();
        for (i, (a, b)) in self.buckets.iter().zip(&earlier.buckets).enumerate() {
            out.buckets[i] = a.saturating_sub(*b);
        }
        out.count = out.buckets.iter().sum();
        out
    }

    /// Mean of the bucket upper edges weighted by count, in seconds —
    /// a cheap central estimate for controllers (within 2× like the
    /// percentiles; 0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, &c)| c as f64 * 2f64.powi(i as i32 + 1) * 1e-6)
            .sum();
        sum / self.count as f64
    }

    /// Percentile in [0, 100]: the upper edge, in seconds, of the bucket
    /// holding that rank (0.0 when empty).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return 2f64.powi(i as i32 + 1) * 1e-6;
            }
        }
        0.0
    }
}

/// Fixed-footprint histogram over ratios in [0, 1] — eleven buckets of
/// width 0.1 (the last also catching exactly 1.0). Used for per-wave
/// speculative-decoding acceptance rates: like [`GapHistogram`], it fires
/// for the life of a cartridge, so it must clone in O(1) to ride worker
/// checkpoints.
#[derive(Debug, Clone)]
pub struct RatioHistogram {
    buckets: [u64; 11],
    count: u64,
    sum: f64,
}

impl Default for RatioHistogram {
    fn default() -> Self {
        RatioHistogram { buckets: [0; 11], count: 0, sum: 0.0 }
    }
}

impl RatioHistogram {
    pub fn record(&mut self, ratio: f64) {
        let r = ratio.clamp(0.0, 1.0);
        let idx = ((r * 10.0).floor() as usize).min(10);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += r;
    }

    /// Fold another histogram in (fleet aggregation).
    pub fn merge(&mut self, other: &RatioHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean recorded ratio (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    /// Fraction of samples at or above `lo` (bucket-granular: `lo` rounds
    /// down to its 0.1-wide bucket).
    pub fn fraction_at_least(&self, lo: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let idx = ((lo.clamp(0.0, 1.0) * 10.0).floor() as usize).min(10);
        let in_range: u64 = self.buckets[idx..].iter().sum();
        in_range as f64 / self.count as f64
    }
}

/// Aggregate serving metrics, printed by the server and the e2e bench.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Prompt tokens actually run through device prefill.
    pub tokens_prefilled: u64,
    /// Prompt tokens served from the radix prefix cache instead of being
    /// prefilled. Includes the by-reference prefix of restored checkpoints
    /// — those rows really were served from the cache. Reconciliation:
    /// prompt tokens admitted = `tokens_prefilled + prefill_skipped_tokens`
    /// + the prompt-row share of `restored_tokens` (a by-value resume
    /// rebuilds its prompt rows from the checkpoint, touching neither
    /// prefill nor the cache).
    pub prefill_skipped_tokens: u64,
    /// KV rows rebuilt by value from a migration/resume checkpoint (work
    /// this cartridge did NOT redo: neither prefill nor decode ran for
    /// them).
    pub restored_tokens: u64,
    /// Requests this cartridge resumed from a checkpoint mid-decode.
    pub resumed_requests: u64,
    /// Requests this cartridge exported to another mid-decode.
    pub migrated_out: u64,
    /// Requests preempted mid-flight on this cartridge by a client cancel:
    /// the scheduler evicted the rows and freed the KV pages before the
    /// request finished ([`Scheduler::cancel`]).
    ///
    /// [`Scheduler::cancel`]: super::scheduler::Scheduler::cancel
    pub preempted_requests: u64,
    /// Sequences paged out to the disk spill tier by the KV byte budget
    /// ([`KvMemOpts::budget_bytes`]); one count per spill, so a sequence
    /// that bounces counts each trip.
    ///
    /// [`KvMemOpts::budget_bytes`]: super::scheduler::KvMemOpts::budget_bytes
    pub kv_spills: u64,
    /// Spilled sequences restored into the engine ahead of their next
    /// decode step. In a drained scheduler `kv_unspills` equals
    /// `kv_spills` minus the spilled sequences cancelled or migrated away.
    pub kv_unspills: u64,
    /// Snapshot wire bytes written to the spill file.
    pub kv_spill_bytes: u64,
    /// Snapshot wire bytes read back from the spill file.
    pub kv_unspill_bytes: u64,
    /// KV pages block-quantized by the cold sweep
    /// ([`KvQuantPolicy`](crate::host::kv_cache::KvQuantPolicy)).
    pub kv_pages_quantized: u64,
    /// Quantized pages materialized back to FP32 by a copy-on-write
    /// append (each is a page the hot window gave up early).
    pub kv_pages_materialized: u64,
    /// Wire bytes of full [`KvSnapshot`] periodic checkpoints. Together
    /// with `ckpt_delta_bytes` this prices the delta-checkpoint win:
    /// all-full checkpointing would cost O(context) per interval.
    ///
    /// [`KvSnapshot`]: crate::host::kv_cache::KvSnapshot
    pub ckpt_full_bytes: u64,
    /// Wire bytes of delta periodic checkpoints
    /// ([`KvSnapshotDelta`](crate::host::kv_cache::KvSnapshotDelta)) —
    /// steady-state cost O(tokens per interval).
    pub ckpt_delta_bytes: u64,
    /// Device waves that carried BOTH decode rows and prefill-chunk rows —
    /// iteration-level continuous batching at work. Note this counts wave
    /// *composition*, not the chunking policy: even run-to-completion
    /// scheduling (`prefill_chunk_tokens = 0`) mixes a whole prefill into
    /// the iteration's decode waves; only purely sequential traffic (no
    /// prefill ever concurrent with a live decode) keeps it at 0.
    pub mixed_waves: u64,
    /// Prefill chunks scheduled: one per still-prefilling request per
    /// iteration it rode along in. A request whose whole suffix fits one
    /// iteration's budget counts a single chunk.
    pub prefill_chunks: u64,
    pub wall_s: f64,
    pub ttft: LatencyRecorder,
    pub itl: LatencyRecorder,
    /// Per-token decode gaps pooled across requests: for every sampled
    /// decode token, the wall time since that sequence's previous token.
    /// Unlike `itl` (one per-request mean recorded at completion), this
    /// histogram exposes stalls — a long prefill freezing in-flight decodes
    /// shows up as outlier samples here, which is exactly what chunked
    /// prefill bounds (see the `mixed_prefill_decode` sweep in
    /// `BENCH_e2e.json`). Log-bucketed ([`GapHistogram`]) because it fires
    /// once per decoded token forever.
    pub itl_step: GapHistogram,
    /// Queue wait per admitted request: enqueue → admit (the time a request
    /// spent waiting for an active slot, including requeue/migration
    /// round-trips). Log-bucketed so it survives worker checkpoints.
    pub queue_wait: GapHistogram,
    /// Draft tokens proposed by the speculative-decoding draft engine.
    /// Conservation law (pinned by `rust/tests/spec_decode_sim.rs`):
    /// `spec_proposed == spec_accepted + spec_rollbacks`, always.
    pub spec_proposed: u64,
    /// Draft tokens the target verified and accepted into the stream.
    pub spec_accepted: u64,
    /// Draft tokens the target rejected; each had its committed KV row
    /// rolled back ([`truncate_sequence`]). The correction/bonus token the
    /// target samples alongside is counted in `tokens_generated`, not here.
    ///
    /// [`truncate_sequence`]: super::engine::Engine::truncate_sequence
    pub spec_rollbacks: u64,
    /// Per-verify-wave acceptance rate (accepted / proposed) distribution.
    /// Fixed footprint, so it survives worker checkpoints — a dead
    /// cartridge's acceptance profile is not lost with it.
    pub spec_accept: RatioHistogram,
    pub batch_waste: f64,
    /// Pipeline depth of the engine behind these metrics (1 = plain
    /// cartridge). Merging takes the max — a fleet aggregate reports its
    /// deepest pipeline.
    pub pipeline_stages: u64,
    /// Inter-stage activation transfers (0 for K=1).
    pub link_hops: u64,
    /// Bytes moved stage→stage (INT16 hidden states; 0 for K=1).
    pub link_bytes: u64,
    /// Modeled wall time of the inter-stage transfers on the engine's
    /// configured link.
    pub link_time_s: f64,
    /// Stage-slot pairs scheduled (pipeline occupancy denominator; see
    /// [`BatchStats::stage_occupancy`](super::batcher::BatchStats)).
    pub stage_slots: u64,
    /// Stage-slot pairs that carried a wave (occupancy numerator).
    pub stage_busy_slots: u64,
    pub interface_bytes: u64,
    pub device_macs: u64,
    /// Modeled device energy for the run (joules): every MAC the cartridge
    /// — target *and* draft engine — executed, priced at the paper's
    /// Table II ITA stack (4.05 pJ/MAC). Note `device_macs` counts only the
    /// target engine; the draft's MACs appear here but not there.
    pub energy_j: f64,
    /// Full interface ledger of this engine's cartridge, so the paper's
    /// Eq. 7–11 accounting reconciles per device even inside a fleet
    /// (`interface_bytes == traffic.total()`).
    pub traffic: TrafficLedger,
}

impl ServingMetrics {
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_s
    }

    /// Lifetime speculative-decoding acceptance rate
    /// (`spec_accepted / spec_proposed`; 0.0 when nothing was proposed).
    /// The per-wave distribution is in [`spec_accept`](Self::spec_accept).
    pub fn spec_acceptance(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// Fraction of pipeline stage slots that carried a wave. 1.0 for a
    /// plain engine (no fill/drain bubble) or before anything ran.
    pub fn stage_occupancy(&self) -> f64 {
        if self.stage_slots == 0 {
            return 1.0;
        }
        self.stage_busy_slots as f64 / self.stage_slots as f64
    }

    /// Share of the wall clock the modeled inter-stage transfers account
    /// for (0.0 for K=1 or a clockless snapshot).
    pub fn link_share(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.link_time_s / self.wall_s
    }

    /// Clone the counters and ledgers, leaving the per-sample latency
    /// recorders empty. The O(1) snapshot the worker checkpoint path uses:
    /// `ttft`/`itl` store one raw sample per completion, so a full clone
    /// per periodic checkpoint would cost O(requests served) each time.
    /// `itl_step` is a fixed-footprint histogram and survives the
    /// checkpoint, so a dead cartridge's per-token gap distribution is not
    /// lost with it.
    pub fn clone_counters(&self) -> ServingMetrics {
        ServingMetrics {
            requests_completed: self.requests_completed,
            tokens_generated: self.tokens_generated,
            tokens_prefilled: self.tokens_prefilled,
            prefill_skipped_tokens: self.prefill_skipped_tokens,
            restored_tokens: self.restored_tokens,
            resumed_requests: self.resumed_requests,
            migrated_out: self.migrated_out,
            preempted_requests: self.preempted_requests,
            kv_spills: self.kv_spills,
            kv_unspills: self.kv_unspills,
            kv_spill_bytes: self.kv_spill_bytes,
            kv_unspill_bytes: self.kv_unspill_bytes,
            kv_pages_quantized: self.kv_pages_quantized,
            kv_pages_materialized: self.kv_pages_materialized,
            ckpt_full_bytes: self.ckpt_full_bytes,
            ckpt_delta_bytes: self.ckpt_delta_bytes,
            mixed_waves: self.mixed_waves,
            prefill_chunks: self.prefill_chunks,
            wall_s: self.wall_s,
            ttft: LatencyRecorder::default(),
            itl: LatencyRecorder::default(),
            itl_step: self.itl_step.clone(),
            queue_wait: self.queue_wait.clone(),
            spec_proposed: self.spec_proposed,
            spec_accepted: self.spec_accepted,
            spec_rollbacks: self.spec_rollbacks,
            spec_accept: self.spec_accept.clone(),
            batch_waste: self.batch_waste,
            pipeline_stages: self.pipeline_stages,
            link_hops: self.link_hops,
            link_bytes: self.link_bytes,
            link_time_s: self.link_time_s,
            stage_slots: self.stage_slots,
            stage_busy_slots: self.stage_busy_slots,
            interface_bytes: self.interface_bytes,
            device_macs: self.device_macs,
            energy_j: self.energy_j,
            traffic: self.traffic,
        }
    }

    /// Fold another engine's metrics in. Counters and ledgers sum, latency
    /// samples pool, wall clocks overlap (max), and padding waste averages
    /// weighted by generated tokens.
    pub fn merge(&mut self, other: &ServingMetrics) {
        let (wt_a, wt_b) = (self.tokens_generated as f64, other.tokens_generated as f64);
        if wt_a + wt_b > 0.0 {
            self.batch_waste =
                (self.batch_waste * wt_a + other.batch_waste * wt_b) / (wt_a + wt_b);
        }
        self.requests_completed += other.requests_completed;
        self.tokens_generated += other.tokens_generated;
        self.tokens_prefilled += other.tokens_prefilled;
        self.prefill_skipped_tokens += other.prefill_skipped_tokens;
        self.restored_tokens += other.restored_tokens;
        self.resumed_requests += other.resumed_requests;
        self.migrated_out += other.migrated_out;
        self.preempted_requests += other.preempted_requests;
        self.kv_spills += other.kv_spills;
        self.kv_unspills += other.kv_unspills;
        self.kv_spill_bytes += other.kv_spill_bytes;
        self.kv_unspill_bytes += other.kv_unspill_bytes;
        self.kv_pages_quantized += other.kv_pages_quantized;
        self.kv_pages_materialized += other.kv_pages_materialized;
        self.ckpt_full_bytes += other.ckpt_full_bytes;
        self.ckpt_delta_bytes += other.ckpt_delta_bytes;
        self.mixed_waves += other.mixed_waves;
        self.prefill_chunks += other.prefill_chunks;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        self.itl_step.merge(&other.itl_step);
        self.queue_wait.merge(&other.queue_wait);
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.spec_rollbacks += other.spec_rollbacks;
        self.spec_accept.merge(&other.spec_accept);
        self.pipeline_stages = self.pipeline_stages.max(other.pipeline_stages);
        self.link_hops += other.link_hops;
        self.link_bytes += other.link_bytes;
        self.link_time_s += other.link_time_s;
        self.stage_slots += other.stage_slots;
        self.stage_busy_slots += other.stage_busy_slots;
        self.interface_bytes += other.interface_bytes;
        self.device_macs += other.device_macs;
        self.energy_j += other.energy_j;
        self.traffic.add(&other.traffic);
    }

    /// Modeled device energy for the run (paper Table II ITA pJ/MAC).
    pub fn modeled_device_energy_j(&self, pj_per_mac: f64) -> f64 {
        self.device_macs as f64 * pj_per_mac * 1e-12
    }

    /// Modeled joules per generated token (`energy_j / tokens_generated`;
    /// 0.0 before anything decoded). The serving-side counterpart of the
    /// paper's Table III per-token energy comparison — prefill and draft
    /// work are amortized over the tokens actually delivered.
    pub fn joules_per_token(&self) -> f64 {
        if self.tokens_generated == 0 {
            return 0.0;
        }
        self.energy_j / self.tokens_generated as f64
    }

    /// Every numeric field as a stable `(name, value)` list — the registry
    /// export surface and the anti-drift contract for
    /// [`merge`](Self::merge) / [`clone_counters`](Self::clone_counters).
    ///
    /// The exhaustive destructure (no `..`) is load-bearing: adding a field
    /// to [`ServingMetrics`] without threading it through here is a compile
    /// error, and the field-coverage tests then force it through `merge`
    /// and `clone_counters` too. Histograms/recorders expand to
    /// count + percentile entries.
    pub fn numeric_fields(&self) -> Vec<(&'static str, f64)> {
        let ServingMetrics {
            requests_completed,
            tokens_generated,
            tokens_prefilled,
            prefill_skipped_tokens,
            restored_tokens,
            resumed_requests,
            migrated_out,
            preempted_requests,
            kv_spills,
            kv_unspills,
            kv_spill_bytes,
            kv_unspill_bytes,
            kv_pages_quantized,
            kv_pages_materialized,
            ckpt_full_bytes,
            ckpt_delta_bytes,
            mixed_waves,
            prefill_chunks,
            wall_s,
            ttft,
            itl,
            itl_step,
            queue_wait,
            spec_proposed,
            spec_accepted,
            spec_rollbacks,
            spec_accept,
            batch_waste,
            pipeline_stages,
            link_hops,
            link_bytes,
            link_time_s,
            stage_slots,
            stage_busy_slots,
            interface_bytes,
            device_macs,
            energy_j,
            traffic,
        } = self;
        let TrafficLedger { d2h_bytes, h2d_bytes, protocol_d2h_bytes, protocol_h2d_bytes } =
            traffic;
        vec![
            ("requests_completed", *requests_completed as f64),
            ("tokens_generated", *tokens_generated as f64),
            ("tokens_prefilled", *tokens_prefilled as f64),
            ("prefill_skipped_tokens", *prefill_skipped_tokens as f64),
            ("restored_tokens", *restored_tokens as f64),
            ("resumed_requests", *resumed_requests as f64),
            ("migrated_out", *migrated_out as f64),
            ("preempted_requests", *preempted_requests as f64),
            ("kv_spills", *kv_spills as f64),
            ("kv_unspills", *kv_unspills as f64),
            ("kv_spill_bytes", *kv_spill_bytes as f64),
            ("kv_unspill_bytes", *kv_unspill_bytes as f64),
            ("kv_pages_quantized", *kv_pages_quantized as f64),
            ("kv_pages_materialized", *kv_pages_materialized as f64),
            ("ckpt_full_bytes", *ckpt_full_bytes as f64),
            ("ckpt_delta_bytes", *ckpt_delta_bytes as f64),
            ("mixed_waves", *mixed_waves as f64),
            ("prefill_chunks", *prefill_chunks as f64),
            ("wall_s", *wall_s),
            ("ttft_count", ttft.count() as f64),
            ("ttft_p50_s", ttft.percentile(50.0)),
            ("ttft_p95_s", ttft.percentile(95.0)),
            ("itl_count", itl.count() as f64),
            ("itl_p50_s", itl.percentile(50.0)),
            ("itl_p95_s", itl.percentile(95.0)),
            ("itl_step_count", itl_step.count() as f64),
            ("itl_step_p50_s", itl_step.percentile(50.0)),
            ("itl_step_p99_s", itl_step.percentile(99.0)),
            ("queue_wait_count", queue_wait.count() as f64),
            ("queue_wait_p50_s", queue_wait.percentile(50.0)),
            ("queue_wait_p99_s", queue_wait.percentile(99.0)),
            ("spec_proposed", *spec_proposed as f64),
            ("spec_accepted", *spec_accepted as f64),
            ("spec_rollbacks", *spec_rollbacks as f64),
            ("spec_accept_count", spec_accept.count() as f64),
            ("spec_accept_mean", spec_accept.mean()),
            ("batch_waste", *batch_waste),
            ("pipeline_stages", *pipeline_stages as f64),
            ("link_hops", *link_hops as f64),
            ("link_bytes", *link_bytes as f64),
            ("link_time_s", *link_time_s),
            ("stage_slots", *stage_slots as f64),
            ("stage_busy_slots", *stage_busy_slots as f64),
            ("interface_bytes", *interface_bytes as f64),
            ("device_macs", *device_macs as f64),
            ("energy_j", *energy_j),
            ("traffic_d2h_bytes", *d2h_bytes as f64),
            ("traffic_h2d_bytes", *h2d_bytes as f64),
            ("traffic_protocol_d2h_bytes", *protocol_d2h_bytes as f64),
            ("traffic_protocol_h2d_bytes", *protocol_h2d_bytes as f64),
        ]
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} prefill_tokens={} prefill_skipped={} restored={} resumed={} \
             migrated_out={} preempted={} kv_spills={} kv_unspills={} kv_quant_pages={} \
             ckpt_full={}B ckpt_delta={}B decode_tokens={} mixed_waves={} prefill_chunks={} \
             spec_proposed={} spec_accepted={} spec_rollbacks={} spec_accept_rate={:.2} \
             wall={:.2}s decode_throughput={:.1} tok/s ttft_p50={:.1}ms ttft_p95={:.1}ms \
             itl_p50={:.2}ms itl_p95={:.2}ms itl_step_p99={:.2}ms queue_p99={:.1}ms \
             batch_waste={:.1}% stages={} stage_occupancy={:.2} link_bytes={} \
             interface={:.2} MB device_macs={:.2}G energy={:.3}mJ j_per_tok={:.3}uJ",
            self.requests_completed,
            self.tokens_prefilled,
            self.prefill_skipped_tokens,
            self.restored_tokens,
            self.resumed_requests,
            self.migrated_out,
            self.preempted_requests,
            self.kv_spills,
            self.kv_unspills,
            self.kv_pages_quantized,
            self.ckpt_full_bytes,
            self.ckpt_delta_bytes,
            self.tokens_generated,
            self.mixed_waves,
            self.prefill_chunks,
            self.spec_proposed,
            self.spec_accepted,
            self.spec_rollbacks,
            self.spec_acceptance(),
            self.wall_s,
            self.decode_tok_per_s(),
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(95.0) * 1e3,
            self.itl.percentile(50.0) * 1e3,
            self.itl.percentile(95.0) * 1e3,
            self.itl_step.percentile(99.0) * 1e3,
            self.queue_wait.percentile(99.0) * 1e3,
            self.batch_waste * 100.0,
            self.pipeline_stages.max(1),
            self.stage_occupancy(),
            self.link_bytes,
            self.interface_bytes as f64 / 1e6,
            self.device_macs as f64 / 1e9,
            self.energy_j * 1e3,
            self.joules_per_token() * 1e6,
        )
    }
}

/// One cartridge's slice of a fleet snapshot.
#[derive(Debug, Clone, Default)]
pub struct CartridgeMetrics {
    pub cartridge: usize,
    /// False once the worker died (panic / engine error). Gracefully
    /// drained cartridges report true — they were healthy to the end. A
    /// dead cartridge reports its last periodic metrics checkpoint (work it
    /// verifiably completed); the requests it still held were requeued and
    /// are counted by the survivor that finished them, so decode tokens the
    /// dead cartridge spent on a requeued request appear in both — that is
    /// real work performed, not double-billed completions.
    pub alive: bool,
    pub serving: ServingMetrics,
}

/// Fleet-wide snapshot: per-cartridge breakdowns plus dispatcher counters.
#[derive(Debug, Clone, Default)]
pub struct FleetMetrics {
    pub cartridges: Vec<CartridgeMetrics>,
    /// Requests returned to the admission queue after their cartridge died.
    /// Each is re-dispatched if a healthy cartridge remains; otherwise it is
    /// also counted in `failed_requests`.
    pub requeued_requests: u64,
    /// Requests failed because no healthy cartridge remained.
    pub failed_requests: u64,
    /// Completed live migrations: a request's KV checkpoint moved to a
    /// different cartridge mid-decode (explicit [`Fleet::migrate`] calls
    /// plus automatic [`Rebalance`] moves).
    ///
    /// [`Fleet::migrate`]: super::fleet::Fleet::migrate
    /// [`Rebalance`]: super::fleet::Rebalance
    pub migrations: u64,
    /// Requeued requests that resumed from their last decode checkpoint
    /// instead of restarting at prefill (panic recovery).
    pub checkpoint_resumes: u64,
    /// Requests rejected by admission control before they ever queued
    /// (projected queue wait exceeded the class SLO budget). A shed
    /// request never reaches a device.
    pub shed_requests: u64,
    /// Requests cancelled by their client (explicit cancel or a dropped
    /// token stream) — whether still queued or already in flight.
    pub cancelled_requests: u64,
    /// Trace events lost to recorder-ring/sink overflow or tail-sampling
    /// drops, fleet-wide (0 when tracing is off).
    pub trace_dropped_total: u64,
    /// Live per-tenant × priority-class series from the observability
    /// plane. These sum exactly to the dispatcher counters above (pinned
    /// by `rust/tests/telemetry_sim.rs`).
    pub tenants: Vec<TenantClassMetrics>,
    /// SLO burn-rate alert postures (empty unless
    /// [`FrontDoorOpts::slo`](super::frontdoor::FrontDoorOpts::slo) is
    /// set).
    pub alerts: Vec<AlertSnapshot>,
    /// Dispatcher wall clock.
    pub wall_s: f64,
}

impl FleetMetrics {
    /// Sum of the per-cartridge metrics (wall clocks overlap; the
    /// dispatcher's own wall clock wins).
    pub fn aggregate(&self) -> ServingMetrics {
        let mut total = ServingMetrics::default();
        for c in &self.cartridges {
            total.merge(&c.serving);
        }
        total.wall_s = self.wall_s;
        total
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "fleet: {} cartridges ({} alive), requeued={} failed={} migrations={} \
             checkpoint_resumes={} shed={} cancelled={}\n",
            self.cartridges.len(),
            self.cartridges.iter().filter(|c| c.alive).count(),
            self.requeued_requests,
            self.failed_requests,
            self.migrations,
            self.checkpoint_resumes,
            self.shed_requests,
            self.cancelled_requests,
        );
        for c in &self.cartridges {
            out.push_str(&format!(
                "  cartridge {}{}: {}\n",
                c.cartridge,
                if c.alive { "" } else { " (dead)" },
                c.serving.report()
            ));
        }
        out.push_str(&format!("  total: {}", self.aggregate().report()));
        out
    }
}

/// One cartridge's slice of a [`MetricsSnapshot`].
#[derive(Debug, Clone)]
pub struct CartridgeSnapshot {
    pub cartridge: usize,
    pub alive: bool,
    pub fields: Vec<(&'static str, f64)>,
}

/// The unified telemetry registry: wraps a [`FleetMetrics`] (or a single
/// engine's [`ServingMetrics`] as the n=1 fleet) and renders one
/// [`MetricsSnapshot`] covering fleet counters, the aggregate, derived
/// rates, and per-cartridge breakdowns — the single export surface behind
/// both the JSON snapshot and the Prometheus text exposition.
#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    fleet: FleetMetrics,
}

impl MetricsRegistry {
    pub fn from_fleet(fleet: FleetMetrics) -> MetricsRegistry {
        MetricsRegistry { fleet }
    }

    /// Wrap one engine's metrics as a single-cartridge fleet.
    pub fn from_serving(m: ServingMetrics) -> MetricsRegistry {
        let wall_s = m.wall_s;
        MetricsRegistry {
            fleet: FleetMetrics {
                cartridges: vec![CartridgeMetrics { cartridge: 0, alive: true, serving: m }],
                wall_s,
                ..FleetMetrics::default()
            },
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let fleet = vec![
            ("fleet_cartridges", self.fleet.cartridges.len() as f64),
            (
                "fleet_alive",
                self.fleet.cartridges.iter().filter(|c| c.alive).count() as f64,
            ),
            ("fleet_requeued_requests", self.fleet.requeued_requests as f64),
            ("fleet_failed_requests", self.fleet.failed_requests as f64),
            ("fleet_migrations", self.fleet.migrations as f64),
            ("fleet_checkpoint_resumes", self.fleet.checkpoint_resumes as f64),
            ("fleet_shed_requests", self.fleet.shed_requests as f64),
            ("fleet_cancelled_requests", self.fleet.cancelled_requests as f64),
            ("trace_dropped_total", self.fleet.trace_dropped_total as f64),
            ("fleet_wall_s", self.fleet.wall_s),
        ];
        let agg = self.fleet.aggregate();
        let mut aggregate = agg.numeric_fields();
        aggregate.push(("decode_tok_per_s", agg.decode_tok_per_s()));
        aggregate.push(("spec_acceptance", agg.spec_acceptance()));
        aggregate.push(("stage_occupancy", agg.stage_occupancy()));
        aggregate.push(("link_share", agg.link_share()));
        aggregate.push(("joules_per_token", agg.joules_per_token()));
        let cartridges = self
            .fleet
            .cartridges
            .iter()
            .map(|c| CartridgeSnapshot {
                cartridge: c.cartridge,
                alive: c.alive,
                fields: c.serving.numeric_fields(),
            })
            .collect();
        MetricsSnapshot {
            fleet,
            aggregate,
            cartridges,
            tenants: self.fleet.tenants.clone(),
            alerts: self.fleet.alerts.clone(),
        }
    }
}

/// A rendered, self-contained metrics snapshot (plain numbers — safe to
/// serialize, diff, or ship to a scraper).
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Dispatcher-level counters (`fleet_*`).
    pub fleet: Vec<(&'static str, f64)>,
    /// Fleet aggregate: every [`ServingMetrics::numeric_fields`] entry plus
    /// derived rates (`decode_tok_per_s`, `joules_per_token`, …).
    pub aggregate: Vec<(&'static str, f64)>,
    /// Per-cartridge breakdowns.
    pub cartridges: Vec<CartridgeSnapshot>,
    /// Per-tenant × priority-class labeled series (`tenant=`/`class=`).
    pub tenants: Vec<TenantClassMetrics>,
    /// SLO alert postures (`slo=` labeled).
    pub alerts: Vec<AlertSnapshot>,
}

impl MetricsSnapshot {
    /// Look a value up by name: aggregate entries first, then `fleet_*`.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.aggregate
            .iter()
            .chain(self.fleet.iter())
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
    }

    /// JSON document: `{"schema": "ita-metrics-v1", "fleet": {…},
    /// "aggregate": {…}, "cartridges": [{…}]}`.
    pub fn to_json(&self) -> String {
        use crate::util::json::{json_array, Json};
        let obj = |fields: &[(&'static str, f64)]| {
            let mut j = Json::default();
            for (name, v) in fields {
                j.float_full(name, *v);
            }
            j.encode()
        };
        let cartridges: Vec<String> = self
            .cartridges
            .iter()
            .map(|c| {
                let mut j = Json::default();
                j.num("cartridge", c.cartridge);
                j.bool("alive", c.alive);
                for (name, v) in &c.fields {
                    j.float_full(name, *v);
                }
                j.encode()
            })
            .collect();
        let mut root = Json::default();
        root.str("schema", "ita-metrics-v1");
        root.put("fleet", obj(&self.fleet));
        root.put("aggregate", obj(&self.aggregate));
        root.put("cartridges", json_array(&cartridges));
        root.put("tenants", tenants_json(&self.tenants));
        root.put("alerts", alerts_json(&self.alerts));
        root.encode()
    }

    /// Prometheus text exposition format (version 0.0.4): every metric as
    /// an `ita_`-prefixed gauge, aggregate unlabeled, per-cartridge values
    /// labeled `{cartridge="N"}`, per-tenant series labeled
    /// `{tenant="T",class="C"}`, and SLO alert postures labeled
    /// `{slo="S"}`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.fleet {
            out.push_str(&format!("# TYPE ita_{name} gauge\nita_{name} {v}\n"));
        }
        for (name, v) in &self.aggregate {
            out.push_str(&format!("# TYPE ita_{name} gauge\nita_{name} {v}\n"));
            for c in &self.cartridges {
                if let Some((_, cv)) = c.fields.iter().find(|(n, _)| n == name) {
                    out.push_str(&format!(
                        "ita_{name}{{cartridge=\"{}\"}} {cv}\n",
                        c.cartridge
                    ));
                }
            }
        }
        type TenantField = (&'static str, fn(&TenantClassMetrics) -> f64);
        let tenant_fields: &[TenantField] = &[
            ("tenant_admitted", |t| t.admitted as f64),
            ("tenant_requests_completed", |t| t.requests_completed as f64),
            ("tenant_tokens_generated", |t| t.tokens_generated as f64),
            ("tenant_shed", |t| t.shed as f64),
            ("tenant_cancelled", |t| t.cancelled as f64),
            ("tenant_requeued", |t| t.requeued as f64),
            ("tenant_migrated", |t| t.migrated as f64),
            ("tenant_queue_wait_p99_s", |t| t.queue_wait.percentile(99.0)),
            ("tenant_itl_p99_s", |t| t.itl.percentile(99.0)),
        ];
        if !self.tenants.is_empty() {
            for (name, field) in tenant_fields {
                out.push_str(&format!("# TYPE ita_{name} gauge\n"));
                for t in &self.tenants {
                    out.push_str(&format!(
                        "ita_{name}{{tenant=\"{}\",class=\"{}\"}} {}\n",
                        t.tenant,
                        t.class,
                        field(t)
                    ));
                }
            }
        }
        type AlertField = (&'static str, fn(&AlertSnapshot) -> f64);
        let alert_fields: &[AlertField] = &[
            ("slo_alert_firing", |a| (a.state == AlertState::Firing) as u64 as f64),
            ("slo_burn_fast", |a| a.fast_burn),
            ("slo_burn_slow", |a| a.slow_burn),
        ];
        if !self.alerts.is_empty() {
            for (name, field) in alert_fields {
                out.push_str(&format!("# TYPE ita_{name} gauge\n"));
                for a in &self.alerts {
                    out.push_str(&format!(
                        "ita_{name}{{slo=\"{}\"}} {}\n",
                        a.slo,
                        field(a)
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_counters_and_pools_samples() {
        let mut a = ServingMetrics {
            requests_completed: 2,
            tokens_generated: 10,
            wall_s: 1.0,
            interface_bytes: 100,
            device_macs: 1000,
            batch_waste: 0.5,
            mixed_waves: 4,
            prefill_chunks: 6,
            ..Default::default()
        };
        a.ttft.record(0.1);
        a.itl_step.record(0.01);
        let mut b = ServingMetrics {
            requests_completed: 3,
            tokens_generated: 30,
            wall_s: 2.0,
            interface_bytes: 50,
            device_macs: 500,
            batch_waste: 0.1,
            mixed_waves: 1,
            prefill_chunks: 2,
            ..Default::default()
        };
        b.ttft.record(0.2);
        b.ttft.record(0.3);
        b.itl_step.record(0.02);
        a.merge(&b);
        assert_eq!(a.requests_completed, 5);
        assert_eq!(a.tokens_generated, 40);
        assert_eq!(a.interface_bytes, 150);
        assert_eq!(a.device_macs, 1500);
        assert_eq!(a.ttft.count(), 3);
        assert_eq!(a.mixed_waves, 5);
        assert_eq!(a.prefill_chunks, 8);
        assert_eq!(a.itl_step.count(), 2);
        assert!((a.wall_s - 2.0).abs() < 1e-12, "wall clocks overlap");
        // 0.5 weighted 10 + 0.1 weighted 30 = 0.2
        assert!((a.batch_waste - 0.2).abs() < 1e-9);
    }

    #[test]
    fn fleet_aggregate_sums_cartridges() {
        let mut fm = FleetMetrics { wall_s: 3.0, ..Default::default() };
        for i in 0..3 {
            fm.cartridges.push(CartridgeMetrics {
                cartridge: i,
                alive: true,
                serving: ServingMetrics {
                    requests_completed: (i + 1) as u64,
                    tokens_generated: 10,
                    ..Default::default()
                },
            });
        }
        let total = fm.aggregate();
        assert_eq!(total.requests_completed, 6);
        assert_eq!(total.tokens_generated, 30);
        assert!((total.wall_s - 3.0).abs() < 1e-12);
        assert!(fm.report().contains("cartridge 2"));
    }

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(95.0) - 95.0).abs() <= 1.0);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let r = LatencyRecorder::default();
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.mean(), 0.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: a NaN sample used to kill the whole recorder —
        // `sort_by(partial_cmp().unwrap())` panicked on the first query.
        // Non-finite samples are now dropped at record time.
        let mut r = LatencyRecorder::default();
        r.record(0.2);
        r.record(f64::NAN);
        r.record(0.1);
        r.record(f64::INFINITY);
        r.record(f64::NEG_INFINITY);
        assert_eq!(r.count(), 2, "non-finite samples are dropped");
        assert_eq!(r.percentile(0.0), 0.1);
        assert_eq!(r.percentile(100.0), 0.2);
        assert!((r.mean() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        // regression: p > 100 used to compute an index past the end and
        // panic; p < 0 underflowed toward wrap. Both now clamp.
        let mut r = LatencyRecorder::default();
        for i in 1..=10 {
            r.record(i as f64);
        }
        assert_eq!(r.percentile(110.0), 10.0);
        assert_eq!(r.percentile(f64::INFINITY), 10.0);
        assert_eq!(r.percentile(-5.0), 1.0);
        assert_eq!(r.percentile(f64::NAN), 1.0, "NaN p clamps to the floor");
    }

    #[test]
    fn gap_histogram_buckets_and_percentiles() {
        let mut h = GapHistogram::default();
        assert_eq!(h.percentile(99.0), 0.0);
        // 99 fast samples (~100 µs) and one enormous stall (~1 s)
        for _ in 0..99 {
            h.record(100e-6);
        }
        h.record(1.0);
        assert_eq!(h.count(), 100);
        // p50 lands in the fast bucket: upper edge within 2x of 100 µs
        let p50 = h.percentile(50.0);
        assert!(p50 >= 100e-6 && p50 <= 400e-6, "p50 = {p50}");
        // the stall dominates the max, within 2x of 1 s
        let max = h.percentile(100.0);
        assert!(max >= 1.0 && max <= 4.0, "max = {max}");
        // merge pools counts
        let mut other = GapHistogram::default();
        other.record(100e-6);
        h.merge(&other);
        assert_eq!(h.count(), 101);
        // sub-microsecond and zero gaps land in the smallest bucket
        let mut tiny = GapHistogram::default();
        tiny.record(0.0);
        tiny.record(1e-9);
        assert_eq!(tiny.count(), 2);
        assert!(tiny.percentile(100.0) <= 4e-6);
    }

    #[test]
    fn gap_histogram_diff_yields_interval_samples() {
        // cumulative histogram at t0, more samples by t1: diff isolates the
        // interval — the controller input for adaptive prefill
        let mut h = GapHistogram::default();
        h.record(100e-6);
        h.record(100e-6);
        let snap = h.clone();
        h.record(1.0);
        h.record(1.0);
        h.record(1.0);
        let d = h.diff(&snap);
        assert_eq!(d.count(), 3);
        // the interval was all slow samples; the old fast ones are gone
        assert!(d.percentile(0.0) >= 1.0, "p0 = {}", d.percentile(0.0));
        // diff against a non-prefix saturates instead of wrapping
        let mut other = GapHistogram::default();
        for _ in 0..10 {
            other.record(100e-6);
        }
        let sat = h.diff(&other);
        assert_eq!(sat.count(), 3, "fast bucket clamped at 0, slow kept");
        // empty diff empty is empty; mean of empty is 0
        assert_eq!(GapHistogram::default().diff(&GapHistogram::default()).count(), 0);
        assert_eq!(GapHistogram::default().mean(), 0.0);
        // mean is the count-weighted bucket upper edge (within 2x)
        let mut m = GapHistogram::default();
        m.record(100e-6);
        assert!(m.mean() >= 100e-6 && m.mean() <= 400e-6, "mean = {}", m.mean());
    }

    #[test]
    fn ratio_histogram_buckets_means_and_merges() {
        let mut h = RatioHistogram::default();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.fraction_at_least(0.0), 0.0);
        h.record(0.0);
        h.record(0.25);
        h.record(0.25);
        h.record(1.0); // exactly 1.0 lands in the top bucket, not past it
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 0.375).abs() < 1e-9);
        assert!((h.fraction_at_least(0.2) - 0.75).abs() < 1e-9);
        assert!((h.fraction_at_least(1.0) - 0.25).abs() < 1e-9);
        // out-of-range samples clamp instead of panicking
        h.record(-0.5);
        h.record(7.0);
        assert_eq!(h.count(), 6);
        let mut other = RatioHistogram::default();
        other.record(0.5);
        h.merge(&other);
        assert_eq!(h.count(), 7);
    }

    #[test]
    fn merging_empty_histograms_is_identity() {
        // empty ⊕ empty stays empty; populated ⊕ empty is unchanged
        let mut g = GapHistogram::default();
        g.merge(&GapHistogram::default());
        assert_eq!(g.count(), 0);
        assert_eq!(g.percentile(50.0), 0.0);
        g.record(100e-6);
        let before = g.percentile(100.0);
        g.merge(&GapHistogram::default());
        assert_eq!(g.count(), 1);
        assert_eq!(g.percentile(100.0), before);
        let mut r = RatioHistogram::default();
        r.merge(&RatioHistogram::default());
        assert_eq!(r.count(), 0);
        assert_eq!(r.mean(), 0.0);
        r.record(0.5);
        r.merge(&RatioHistogram::default());
        assert_eq!(r.count(), 1);
        assert!((r.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gap_histogram_power_of_two_boundaries() {
        // a sample at exactly 2^i µs belongs to bucket i (half-open
        // [2^i, 2^(i+1)) ranges), so its reported upper edge is 2^(i+1) µs
        for i in [0, 3, 10] {
            let mut h = GapHistogram::default();
            h.record(2f64.powi(i) * 1e-6);
            let edge = h.percentile(100.0);
            let expect = 2f64.powi(i + 1) * 1e-6;
            assert!(
                (edge - expect).abs() < expect * 1e-9,
                "2^{i} µs reported edge {edge}, want {expect}"
            );
        }
        // just under a boundary stays in the lower bucket
        let mut h = GapHistogram::default();
        h.record(8e-6 * 0.999);
        assert!((h.percentile(100.0) - 8e-6).abs() < 1e-12);
    }

    #[test]
    fn ratio_histogram_boundary_at_one() {
        // exactly 1.0 lands in the top bucket (index 10), and the
        // at-least query at 1.0 sees only those samples
        let mut h = RatioHistogram::default();
        h.record(1.0);
        h.record(0.999); // bucket 9
        h.record(0.9); // bucket 9 (half-open lower edge)
        assert_eq!(h.count(), 3);
        assert!((h.fraction_at_least(1.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((h.fraction_at_least(0.9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn counter_snapshot_keeps_histograms_drops_exact_recorders() {
        // the worker-checkpoint strip: itl_step / spec_accept (fixed
        // footprint) survive, ttft / itl (per-sample) are emptied
        let mut m = ServingMetrics::default();
        m.ttft.record(0.1);
        m.itl.record(0.01);
        m.itl_step.record(0.002);
        m.itl_step.record(0.004);
        m.spec_accept.record(0.75);
        let c = m.clone_counters();
        assert_eq!(c.ttft.count(), 0, "exact recorders are dropped");
        assert_eq!(c.itl.count(), 0);
        assert_eq!(c.itl_step.count(), 2, "itl_step survives the strip");
        assert_eq!(
            c.itl_step.percentile(100.0),
            m.itl_step.percentile(100.0),
            "bucket contents survive, not just counts"
        );
        assert_eq!(c.spec_accept.count(), 1, "spec_accept survives the strip");
        assert!((c.spec_accept.mean() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pipeline_fields_merge_and_report() {
        let mut a = ServingMetrics {
            pipeline_stages: 2,
            link_hops: 10,
            link_bytes: 1000,
            link_time_s: 0.5,
            stage_slots: 30,
            stage_busy_slots: 20,
            wall_s: 2.0,
            ..Default::default()
        };
        let b = ServingMetrics {
            pipeline_stages: 4,
            link_hops: 5,
            link_bytes: 500,
            link_time_s: 0.25,
            stage_slots: 10,
            stage_busy_slots: 10,
            wall_s: 1.0,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.pipeline_stages, 4, "deepest pipeline wins");
        assert_eq!(a.link_hops, 15);
        assert_eq!(a.link_bytes, 1500);
        assert!((a.link_time_s - 0.75).abs() < 1e-12);
        assert!((a.stage_occupancy() - 0.75).abs() < 1e-12);
        assert!((a.link_share() - 0.375).abs() < 1e-12);
        // counter snapshots carry the pipeline fields
        let c = a.clone_counters();
        assert_eq!(c.pipeline_stages, 4);
        assert_eq!(c.link_bytes, 1500);
        assert_eq!(c.stage_slots, 40);
        assert!(a.report().contains("stage_occupancy=0.75"));
        // a plain engine's snapshot reports occupancy 1.0, link share 0
        let plain = ServingMetrics::default();
        assert_eq!(plain.stage_occupancy(), 1.0);
        assert_eq!(plain.link_share(), 0.0);
    }

    #[test]
    fn spec_counters_sum_and_survive_counter_snapshots() {
        let mut a = ServingMetrics {
            spec_proposed: 10,
            spec_accepted: 7,
            spec_rollbacks: 3,
            ..Default::default()
        };
        a.spec_accept.record(0.7);
        let b = ServingMetrics {
            spec_proposed: 4,
            spec_accepted: 1,
            spec_rollbacks: 3,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.spec_proposed, 14);
        assert_eq!(a.spec_accepted, 8);
        assert_eq!(a.spec_rollbacks, 6);
        assert_eq!(a.spec_proposed, a.spec_accepted + a.spec_rollbacks);
        assert!((a.spec_acceptance() - 8.0 / 14.0).abs() < 1e-9);
        // the checkpoint path keeps the fixed-footprint speculation metrics
        let c = a.clone_counters();
        assert_eq!(c.spec_proposed, 14);
        assert_eq!(c.spec_accept.count(), 1);
        assert!(a.report().contains("spec_accept_rate=0.57"));
        // draft-less metrics read as a clean zero, not NaN
        assert_eq!(ServingMetrics::default().spec_acceptance(), 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = ServingMetrics {
            tokens_generated: 100,
            wall_s: 4.0,
            ..Default::default()
        };
        assert!((m.decode_tok_per_s() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn energy_model_hookup() {
        let m = ServingMetrics { device_macs: 1_000_000_000_000, ..Default::default() };
        // 1e12 MACs × 4.05 pJ = 4.05 J
        assert!((m.modeled_device_energy_j(4.05) - 4.05).abs() < 1e-9);
    }

    #[test]
    fn joules_per_token_math() {
        let m = ServingMetrics { tokens_generated: 10, energy_j: 0.05, ..Default::default() };
        assert!((m.joules_per_token() - 0.005).abs() < 1e-12);
        assert_eq!(ServingMetrics::default().joules_per_token(), 0.0, "no tokens, no NaN");
        let mut a = ServingMetrics { energy_j: 1.0, ..Default::default() };
        a.merge(&ServingMetrics { energy_j: 0.5, ..Default::default() });
        assert!((a.energy_j - 1.5).abs() < 1e-12, "merge sums energy");
    }

    /// Every field nonzero, via an exhaustive literal (no `..`): adding a
    /// [`ServingMetrics`] field without updating this fixture — and through
    /// it the merge / clone_counters coverage tests — is a compile error.
    fn fully_populated() -> ServingMetrics {
        ServingMetrics {
            requests_completed: 3,
            tokens_generated: 41,
            tokens_prefilled: 37,
            prefill_skipped_tokens: 11,
            restored_tokens: 5,
            resumed_requests: 2,
            migrated_out: 1,
            preempted_requests: 4,
            kv_spills: 6,
            kv_unspills: 5,
            kv_spill_bytes: 8192,
            kv_unspill_bytes: 7168,
            kv_pages_quantized: 21,
            kv_pages_materialized: 3,
            ckpt_full_bytes: 16384,
            ckpt_delta_bytes: 1024,
            mixed_waves: 7,
            prefill_chunks: 13,
            wall_s: 2.5,
            ttft: {
                let mut r = LatencyRecorder::default();
                r.record(0.125);
                r
            },
            itl: {
                let mut r = LatencyRecorder::default();
                r.record(0.03);
                r
            },
            itl_step: {
                let mut h = GapHistogram::default();
                h.record(0.002);
                h
            },
            queue_wait: {
                let mut h = GapHistogram::default();
                h.record(0.05);
                h
            },
            spec_proposed: 17,
            spec_accepted: 12,
            spec_rollbacks: 5,
            spec_accept: {
                let mut h = RatioHistogram::default();
                h.record(0.7);
                h
            },
            batch_waste: 0.25,
            pipeline_stages: 2,
            link_hops: 19,
            link_bytes: 2048,
            link_time_s: 0.125,
            stage_slots: 40,
            stage_busy_slots: 30,
            interface_bytes: 4096,
            device_macs: 1_000_000,
            energy_j: 0.004,
            traffic: TrafficLedger {
                d2h_bytes: 100,
                h2d_bytes: 200,
                protocol_d2h_bytes: 30,
                protocol_h2d_bytes: 40,
            },
        }
    }

    #[test]
    fn merge_covers_every_numeric_field() {
        // merging a fully-populated snapshot into a default one must move
        // every exported numeric field off zero — a field added to the
        // struct but forgotten in merge() shows up here as a stuck zero
        let mut merged = ServingMetrics::default();
        merged.merge(&fully_populated());
        for (name, v) in merged.numeric_fields() {
            assert!(v != 0.0, "field {name} did not participate in merge");
        }
    }

    #[test]
    fn counter_snapshot_covers_every_numeric_field() {
        // clone_counters may drop ONLY the per-sample recorders (ttft/itl);
        // every other field must survive the checkpoint strip bit-exact
        let dropped = [
            "ttft_count",
            "ttft_p50_s",
            "ttft_p95_s",
            "itl_count",
            "itl_p50_s",
            "itl_p95_s",
        ];
        let full = fully_populated();
        let snap = full.clone_counters();
        for ((name, before), (n2, after)) in
            full.numeric_fields().iter().zip(snap.numeric_fields())
        {
            assert_eq!(*name, n2);
            if dropped.contains(name) {
                assert_eq!(after, 0.0, "{name} should be stripped by clone_counters");
            } else {
                assert!(*before != 0.0, "{name} not populated by the fixture");
                assert!(
                    (before - after).abs() < 1e-12,
                    "{name} was dropped by clone_counters ({before} -> {after})"
                );
            }
        }
    }

    #[test]
    fn registry_snapshot_exports_json_and_prometheus() {
        use crate::util::json::parse;
        let fm = FleetMetrics {
            cartridges: vec![
                CartridgeMetrics { cartridge: 0, alive: true, serving: fully_populated() },
                CartridgeMetrics {
                    cartridge: 1,
                    alive: false,
                    serving: ServingMetrics::default(),
                },
            ],
            migrations: 1,
            wall_s: 2.0,
            ..Default::default()
        };
        let snap = MetricsRegistry::from_fleet(fm).snapshot();
        assert_eq!(snap.get("requests_completed"), Some(3.0));
        assert_eq!(snap.get("fleet_cartridges"), Some(2.0));
        assert_eq!(snap.get("fleet_alive"), Some(1.0));
        assert!(snap.get("joules_per_token").expect("derived entry") > 0.0);
        assert_eq!(snap.get("no_such_metric"), None);

        // JSON round-trips through the in-repo parser
        let doc = parse(&snap.to_json()).expect("valid JSON");
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("ita-metrics-v1"));
        assert_eq!(
            doc.get("aggregate")
                .and_then(|a| a.get("tokens_generated"))
                .and_then(|v| v.as_f64()),
            Some(41.0)
        );
        let carts = doc.get("cartridges").and_then(|v| v.as_array()).expect("array");
        assert_eq!(carts.len(), 2);
        assert_eq!(carts[1].get("alive"), Some(&crate::util::json::JsonValue::Bool(false)));

        // Prometheus exposition: TYPE line, unlabeled aggregate, labeled
        // per-cartridge series
        let prom = snap.to_prometheus();
        assert!(prom.contains("# TYPE ita_tokens_generated gauge"));
        assert!(prom.contains("ita_tokens_generated 41\n"));
        assert!(prom.contains("ita_tokens_generated{cartridge=\"0\"} 41\n"));
        assert!(prom.contains("ita_fleet_migrations 1\n"));

        // n=1 wrapper: one engine's metrics behave as a one-cartridge fleet
        let one = MetricsRegistry::from_serving(fully_populated()).snapshot();
        assert_eq!(one.get("fleet_cartridges"), Some(1.0));
        assert!((one.get("decode_tok_per_s").expect("derived") - 41.0 / 2.5).abs() < 1e-9);
    }

    #[test]
    fn tenant_and_alert_series_export_with_labels() {
        use crate::util::json::parse;
        let mut row = TenantClassMetrics {
            tenant: 42,
            class: "interactive",
            admitted: 6,
            requests_completed: 5,
            tokens_generated: 70,
            shed: 1,
            ..TenantClassMetrics::default()
        };
        row.itl.record(0.004);
        let fm = FleetMetrics {
            trace_dropped_total: 9,
            tenants: vec![row],
            alerts: vec![AlertSnapshot {
                slo: "availability",
                state: AlertState::Firing,
                fast_burn: 12.5,
                slow_burn: 4.0,
                since_s: 1.0,
            }],
            ..Default::default()
        };
        let snap = MetricsRegistry::from_fleet(fm).snapshot();
        assert_eq!(snap.get("trace_dropped_total"), Some(9.0));

        let doc = parse(&snap.to_json()).expect("valid JSON");
        let tenants = doc.get("tenants").and_then(|v| v.as_array()).expect("tenants array");
        assert_eq!(tenants[0].get("tenant").and_then(|v| v.as_f64()), Some(42.0));
        assert_eq!(tenants[0].get("class").and_then(|v| v.as_str()), Some("interactive"));
        assert_eq!(tenants[0].get("tokens_generated").and_then(|v| v.as_f64()), Some(70.0));
        let alerts = doc.get("alerts").and_then(|v| v.as_array()).expect("alerts array");
        assert_eq!(alerts[0].get("state").and_then(|v| v.as_str()), Some("firing"));

        let prom = snap.to_prometheus();
        assert!(prom.contains("ita_trace_dropped_total 9\n"));
        assert!(prom.contains("# TYPE ita_tenant_requests_completed gauge"));
        assert!(prom
            .contains("ita_tenant_requests_completed{tenant=\"42\",class=\"interactive\"} 5\n"));
        assert!(prom.contains("ita_tenant_shed{tenant=\"42\",class=\"interactive\"} 1\n"));
        assert!(prom.contains("ita_slo_alert_firing{slo=\"availability\"} 1\n"));
        assert!(prom.contains("ita_slo_burn_fast{slo=\"availability\"} 12.5\n"));

        // a fleet with no tenants/alerts exports no labeled series at all
        let bare = MetricsRegistry::from_fleet(FleetMetrics::default()).snapshot();
        let prom = bare.to_prometheus();
        assert!(!prom.contains("tenant_"));
        assert!(!prom.contains("slo_"));
    }
}
