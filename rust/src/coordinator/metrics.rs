//! Serving metrics: latency histograms, throughput, traffic.

/// Fixed-capacity latency recorder with percentile queries.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_s: Vec<f64>,
}

impl LatencyRecorder {
    pub fn record(&mut self, seconds: f64) {
        self.samples_s.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples_s.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        self.samples_s.iter().sum::<f64>() / self.samples_s.len() as f64
    }

    /// Percentile in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_s.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_s.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx]
    }
}

/// Aggregate serving metrics, printed by the server and the e2e bench.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub tokens_prefilled: u64,
    pub wall_s: f64,
    pub ttft: LatencyRecorder,
    pub itl: LatencyRecorder,
    pub batch_waste: f64,
    pub interface_bytes: u64,
    pub device_macs: u64,
}

impl ServingMetrics {
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_s
    }

    /// Modeled device energy for the run (paper Table II ITA pJ/MAC).
    pub fn modeled_device_energy_j(&self, pj_per_mac: f64) -> f64 {
        self.device_macs as f64 * pj_per_mac * 1e-12
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} prefill_tokens={} decode_tokens={} wall={:.2}s \
             decode_throughput={:.1} tok/s ttft_p50={:.1}ms ttft_p95={:.1}ms \
             itl_p50={:.2}ms itl_p95={:.2}ms batch_waste={:.1}% \
             interface={:.2} MB device_macs={:.2}G",
            self.requests_completed,
            self.tokens_prefilled,
            self.tokens_generated,
            self.wall_s,
            self.decode_tok_per_s(),
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(95.0) * 1e3,
            self.itl.percentile(50.0) * 1e3,
            self.itl.percentile(95.0) * 1e3,
            self.batch_waste * 100.0,
            self.interface_bytes as f64 / 1e6,
            self.device_macs as f64 / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(95.0) - 95.0).abs() <= 1.0);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_is_zero() {
        let r = LatencyRecorder::default();
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.mean(), 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = ServingMetrics {
            tokens_generated: 100,
            wall_s: 4.0,
            ..Default::default()
        };
        assert!((m.decode_tok_per_s() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn energy_model_hookup() {
        let m = ServingMetrics { device_macs: 1_000_000_000_000, ..Default::default() };
        // 1e12 MACs × 4.05 pJ = 4.05 J
        assert!((m.modeled_device_energy_j(4.05) - 4.05).abs() < 1e-9);
    }
}
