//! The overload-grade async front door: streaming ingress, priorities,
//! per-tenant fairness, and SLO-driven admission control over a [`Fleet`].
//!
//! ```text
//!   clients ──▶ FrontDoor::submit_with(req, QoS) ─┬─▶ Err(Overloaded)   (shed at the door)
//!                                                 └─▶ TokenStream
//!                    │                                   ▲
//!                    ▼                                   │ per-step StreamItem::Tokens,
//!            admission queue (strict priority,           │ one StreamItem::End
//!            weighted fair queueing per tenant)          │
//!                    │ pump                              │
//!                    ▼                                   │
//!            fleet dispatcher ── WorkerEvent::Tokens ────┘
//!              │        ▲
//!              │        └── checkpoints drive the ITL controller:
//!              │            concurrency cap + adaptive prefill chunk
//!              ▼
//!            cartridge workers (cancel = first-class preemption)
//! ```
//!
//! The front door is pure host-side coordination — the Split-Brain device
//! contract is untouched. Three SLO mechanisms, all optional and all driven
//! by measured telemetry rather than static configuration:
//!
//! * **Admission control** ([`FrontDoorOpts::queue_budget_s`]): projected
//!   queue wait for the arriving priority class (queued admission cost ÷
//!   EWMA fleet drain rate) is compared against the budget; arrivals that
//!   would wait longer are rejected with [`SubmitError::Overloaded`]
//!   *before* they consume queue memory or device work — shedding load
//!   before queues melt, instead of timing out requests after the fact.
//! * **ITL concurrency cap** ([`FrontDoorOpts::target_itl_s`]): measured
//!   per-wave decode latency (the `itl_step` histogram deltas piggybacked
//!   on worker checkpoints) yields a per-row wave cost; the dispatcher caps
//!   concurrent decodes per cartridge at `target_itl / row_cost` so
//!   admitted requests keep their inter-token latency inside the SLO.
//! * **Adaptive prefill** ([`FrontDoorOpts::adaptive_prefill`]):
//!   Sarathi-style — instead of a static
//!   [`prefill_chunk_tokens`](super::scheduler::SchedulerOpts::prefill_chunk_tokens),
//!   the chunk budget is retargeted multiplicatively from the measured wave
//!   latency so prefill work per iteration shrinks (or grows) until mixed
//!   waves fit the ITL target.
//!
//! Scheduling across admitted requests: strict priority between
//! [`Priority`] classes, start-time weighted fair queueing between tenants
//! within a class, FIFO within a tenant. Cancellation (explicit via
//! [`CancelHandle`](super::stream::CancelHandle), or implicit when a client
//! drops its [`TokenStream`]) propagates into the scheduler as first-class
//! preemption: KV pages are freed immediately and the stream ends with the
//! partial result.
//!
//! The full serving contract is documented in `docs/serving-front-door.md`.

use std::fmt;

use anyhow::Result;

use super::fleet::{Dispatch, Fleet, LeastLoaded};
use super::metrics::FleetMetrics;
use super::request::GenRequest;
use super::scheduler::SchedulerOpts;
use super::spec::CartridgeEngines;
use super::stream::TokenStream;
use super::telemetry::{SloSpec, StatusSnapshot};
use super::trace::FleetTrace;
use super::worker::CartridgeId;

/// Priority class of a request. Strict: a queued `Interactive` request is
/// always dispatched before any queued `Standard` one, which beats any
/// `Batch` one. Fairness (weights) applies only *within* a class — across
/// classes there is none by design, so batch traffic can never starve
/// interactive traffic, only the reverse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (chat, completion-as-you-type).
    Interactive,
    /// The default class.
    Standard,
    /// Throughput traffic that tolerates queueing (evals, batch scoring).
    Batch,
}

impl Priority {
    /// Stable label used by the telemetry plane (`class=` in Prometheus,
    /// `"class"` in the status/metrics JSON).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Strict-priority rank: 0 = most urgent. Used as a sort key by the
    /// telemetry plane so snapshots list interactive tenants first.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }
}

/// Quality-of-service envelope for one submission: priority class, tenant,
/// and the tenant's fair-queueing weight within the class (a weight-2
/// tenant drains twice the admission cost per unit service of a weight-1
/// tenant under contention; weights below 1 are clamped to 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QoS {
    pub priority: Priority,
    pub tenant: u64,
    pub weight: u32,
}

impl Default for QoS {
    /// `Standard` priority, tenant 0, weight 1.
    fn default() -> QoS {
        QoS { priority: Priority::Standard, tenant: 0, weight: 1 }
    }
}

impl QoS {
    /// [`Priority::Interactive`], tenant 0, weight 1.
    pub fn interactive() -> QoS {
        QoS { priority: Priority::Interactive, ..QoS::default() }
    }

    /// [`Priority::Batch`], tenant 0, weight 1.
    pub fn batch() -> QoS {
        QoS { priority: Priority::Batch, ..QoS::default() }
    }

    /// Tag this envelope with a tenant id and fair-share weight.
    pub fn for_tenant(mut self, tenant: u64, weight: u32) -> QoS {
        self.tenant = tenant;
        self.weight = weight.max(1);
        self
    }
}

/// Why a streaming submission was rejected at the door.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// Admission control shed the request: the projected queue wait for its
    /// priority class exceeds the configured
    /// [`queue_budget_s`](FrontDoorOpts::queue_budget_s). The request never
    /// reached a device — retry later, with backoff proportional to
    /// `projected_wait_s`.
    Overloaded { projected_wait_s: f64, budget_s: f64 },
    /// The fleet has shut down (or is draining) and accepts no new work.
    Closed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded { projected_wait_s, budget_s } => write!(
                f,
                "overloaded: projected queue wait {projected_wait_s:.3}s exceeds SLO budget {budget_s:.3}s"
            ),
            SubmitError::Closed => write!(f, "fleet is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// SLO configuration for the front door. The default is fully permissive —
/// no shedding, no concurrency cap, static prefill chunking — which makes
/// [`FrontDoor`] a drop-in streaming wrapper over [`Fleet`].
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontDoorOpts {
    /// Target inter-token latency (seconds). When set, the dispatcher caps
    /// concurrent decodes per cartridge from measured wave latency, and
    /// [`adaptive_prefill`](FrontDoorOpts::adaptive_prefill) retargets the
    /// prefill chunk against this budget.
    pub target_itl_s: Option<f64>,
    /// Queue-wait SLO budget (seconds). When set, streaming submissions
    /// whose projected wait exceeds it are rejected with
    /// [`SubmitError::Overloaded`]. Unset ⇒ never shed.
    pub queue_budget_s: Option<f64>,
    /// Retarget each cartridge's prefill chunk budget from measured wave
    /// latency (requires [`target_itl_s`](FrontDoorOpts::target_itl_s)).
    pub adaptive_prefill: bool,
    /// Service-level objectives for the live observability plane. When
    /// set, the dispatcher evaluates multi-window burn-rate alerts over
    /// the declared targets and surfaces them in
    /// [`FleetMetrics::alerts`](super::metrics::FleetMetrics::alerts) and
    /// [`StatusSnapshot::alerts`]. Unset ⇒ labeled series only, no
    /// alerting.
    pub slo: Option<SloSpec>,
    /// Switch the fleet trace sink to tail-based sampling with this hard
    /// event budget (see
    /// [`TailSampler`](super::trace::TailSampler)): complete chains are
    /// retained only for slow, shed, cancelled, migrated, or requeued
    /// requests (plus a head-sampled cross-section), making always-on
    /// tracing production-viable. Requires
    /// [`trace_capacity`](super::scheduler::SchedulerOpts::trace_capacity)
    /// to be set; unset ⇒ the sink retains everything (post-mortem mode).
    pub trace_tail_budget: Option<usize>,
}

/// Streaming, SLO-aware ingress over a [`Fleet`] — see the
/// [module docs](self) for the architecture and `docs/serving-front-door.md`
/// for the full serving contract.
///
/// ```
/// use ita::config::ModelConfig;
/// use ita::coordinator::engine::Engine;
/// use ita::coordinator::frontdoor::{FrontDoor, FrontDoorOpts};
/// use ita::coordinator::request::GenRequest;
/// use ita::coordinator::scheduler::SchedulerOpts;
/// use ita::coordinator::stream::StreamItem;
///
/// let door = FrontDoor::start(
///     2,
///     |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 8)),
///     SchedulerOpts::default(),
///     FrontDoorOpts::default(),
/// )
/// .unwrap();
///
/// let mut stream = door.submit(GenRequest::greedy(0, "hello ita", 8)).unwrap();
/// let mut streamed = Vec::new();
/// let result = loop {
///     match stream.recv() {
///         Some(StreamItem::Tokens(t)) => streamed.extend(t),
///         Some(StreamItem::End(r)) => break *r,
///         None => panic!("stream severed before completion"),
///     }
/// };
/// // the incremental tokens concatenate to exactly the final output
/// assert_eq!(streamed, result.tokens);
/// door.shutdown().unwrap();
/// ```
pub struct FrontDoor {
    fleet: Fleet,
}

impl FrontDoor {
    /// Boot `n` cartridges behind a streaming front door with the default
    /// least-loaded dispatch policy.
    pub fn start<F, B>(
        n: usize,
        factory: F,
        opts: SchedulerOpts,
        door: FrontDoorOpts,
    ) -> Result<FrontDoor>
    where
        B: Into<CartridgeEngines> + 'static,
        F: Fn(CartridgeId) -> Result<B> + Send + Sync + 'static,
    {
        FrontDoor::with_dispatch(n, factory, opts, Box::new(LeastLoaded), door)
    }

    /// [`FrontDoor::start`] with an explicit [`Dispatch`] policy.
    ///
    /// Token streaming is forced on in the scheduler options — the front
    /// door is precisely the consumer the scheduler's streaming buffer
    /// exists for.
    pub fn with_dispatch<F, B>(
        n: usize,
        factory: F,
        mut opts: SchedulerOpts,
        dispatch: Box<dyn Dispatch>,
        door: FrontDoorOpts,
    ) -> Result<FrontDoor>
    where
        B: Into<CartridgeEngines> + 'static,
        F: Fn(CartridgeId) -> Result<B> + Send + Sync + 'static,
    {
        opts.stream_tokens = true;
        Ok(FrontDoor { fleet: Fleet::boot(n, factory, opts, dispatch, door)? })
    }

    /// Submit with default [`QoS`] (standard priority, tenant 0).
    pub fn submit(&self, req: GenRequest) -> Result<TokenStream, SubmitError> {
        self.fleet.submit_stream(req, QoS::default())
    }

    /// Submit with an explicit [`QoS`] envelope. Subject to admission
    /// control when a queue budget is configured; returns the token stream
    /// only for admitted requests.
    ///
    /// ```
    /// use ita::config::ModelConfig;
    /// use ita::coordinator::engine::Engine;
    /// use ita::coordinator::frontdoor::{FrontDoor, FrontDoorOpts, QoS};
    /// use ita::coordinator::request::GenRequest;
    /// use ita::coordinator::scheduler::SchedulerOpts;
    ///
    /// let door = FrontDoor::start(
    ///     1,
    ///     |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 8)),
    ///     SchedulerOpts::default(),
    ///     FrontDoorOpts::default(),
    /// )
    /// .unwrap();
    ///
    /// let stream = door
    ///     .submit_with(
    ///         GenRequest::greedy(1, "deadline-sensitive", 64),
    ///         QoS::interactive().for_tenant(42, 2),
    ///     )
    ///     .unwrap();
    ///
    /// // a watchdog can preempt from another thread at any time; the
    /// // stream then ends with a partial result marked Cancelled
    /// let watchdog = stream.cancel_handle();
    /// watchdog.cancel();
    /// let partial = stream.wait().unwrap();
    /// assert_eq!(partial.finish, ita::coordinator::request::FinishReason::Cancelled);
    /// door.shutdown().unwrap();
    /// ```
    pub fn submit_with(&self, req: GenRequest, qos: QoS) -> Result<TokenStream, SubmitError> {
        self.fleet.submit_stream(req, qos)
    }

    /// The wrapped fleet, for unary submission, explicit migration, or
    /// anything else the streaming surface doesn't cover.
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Number of cartridges behind the door.
    pub fn cartridges(&self) -> usize {
        self.fleet.cartridges()
    }

    /// Aggregated fleet metrics (includes `shed_requests` /
    /// `cancelled_requests`).
    pub fn metrics(&self) -> Result<FleetMetrics> {
        self.fleet.metrics()
    }

    /// The live control-room view: per-cartridge occupancy, per-lane
    /// queue depths, drain-rate EWMA, SLO alert states, the per-tenant ×
    /// class series, and the flight-recorder tail. Positional (what is
    /// happening *now*) where [`metrics`](FrontDoor::metrics) is
    /// cumulative; `serve_fleet --status-port` serves it as JSON.
    pub fn status(&self) -> Result<StatusSnapshot> {
        self.fleet.status()
    }

    /// Drain in-flight work and stop every cartridge.
    pub fn shutdown(self) -> Result<FleetMetrics> {
        self.fleet.shutdown()
    }

    /// [`FrontDoor::shutdown`], also returning the fleet-wide trace.
    pub fn shutdown_traced(self) -> Result<(FleetMetrics, FleetTrace)> {
        self.fleet.shutdown_traced()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qos_constructors_and_ordering() {
        assert!(Priority::Interactive < Priority::Standard);
        assert!(Priority::Standard < Priority::Batch);
        let q = QoS::default();
        assert_eq!((q.priority, q.tenant, q.weight), (Priority::Standard, 0, 1));
        assert_eq!(QoS::interactive().priority, Priority::Interactive);
        assert_eq!(QoS::batch().priority, Priority::Batch);
        let t = QoS::batch().for_tenant(7, 0);
        assert_eq!((t.tenant, t.weight), (7, 1), "weight 0 clamps to 1");
    }

    #[test]
    fn submit_error_displays_the_slo_math() {
        let e = SubmitError::Overloaded { projected_wait_s: 1.25, budget_s: 0.5 };
        let msg = e.to_string();
        assert!(msg.contains("1.250"), "{msg}");
        assert!(msg.contains("0.500"), "{msg}");
        assert_eq!(SubmitError::Closed.to_string(), "fleet is shut down");
    }

    #[test]
    fn default_opts_are_fully_permissive() {
        let o = FrontDoorOpts::default();
        assert!(o.target_itl_s.is_none());
        assert!(o.queue_budget_s.is_none());
        assert!(!o.adaptive_prefill);
        assert!(o.slo.is_none());
        assert!(o.trace_tail_budget.is_none());
    }

    #[test]
    fn priority_labels_are_stable() {
        assert_eq!(Priority::Interactive.name(), "interactive");
        assert_eq!(Priority::Standard.name(), "standard");
        assert_eq!(Priority::Batch.name(), "batch");
        assert!(Priority::Interactive.rank() < Priority::Batch.rank());
    }
}
