//! Request-lifecycle tracing: a lock-cheap, ring-buffered event recorder the
//! scheduler stamps on the hot path, plus the fleet-level exporters that turn
//! the collected events into a Chrome/Perfetto timeline, and a
//! flight-recorder dump of the slowest requests.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.** Every instrumentation site is gated on
//!    [`TraceRecorder::enabled`] (an inlined bool load); no timestamps are
//!    taken and no allocation happens on the disabled path. The bench sweep
//!    pins the disabled-overhead claim (`tracing_overhead` record in
//!    `BENCH_e2e.json`).
//! 2. **Bounded memory.** Events land in a fixed-capacity ring; when full,
//!    the oldest events are dropped and counted (`dropped`). Workers drain
//!    their rings into [`CheckpointReport`]s, so in steady state the ring
//!    only holds one checkpoint interval's worth of events.
//! 3. **One shared clock.** All timestamps are µs since a single trace
//!    epoch ([`SchedulerOpts::trace_epoch`], injected by the fleet before
//!    workers boot), so cross-cartridge causality (export before resume,
//!    migrate between the two) holds in the merged timeline.
//!
//! Events are flat [`Copy`] structs — a kind tag plus two generic operands
//! (`a`, `b`) whose meaning is per-kind (see [`TraceKind`]). This keeps the
//! ring allocation-free and the recorder branch-cheap.
//!
//! [`CheckpointReport`]: super::worker::CheckpointReport
//! [`SchedulerOpts::trace_epoch`]: super::scheduler::SchedulerOpts::trace_epoch

use std::collections::VecDeque;
use std::time::Instant;

use crate::util::json::{json_array, Json};

/// Sentinel for events not tied to a request (wave/stage spans).
pub const REQ_NONE: u64 = u64::MAX;
/// Sentinel for events not tied to a wave.
pub const WAVE_NONE: u64 = u64::MAX;

/// What happened. The `a`/`b` operand meaning is listed per variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Request left the queue and became active. `a` = queue wait (µs),
    /// `b` = prompt tokens.
    Admit,
    /// Span: enqueue → admit (duration = queue wait).
    Queued,
    /// Span: admit → complete (active service time). `a` = tokens generated.
    Active,
    /// One prefill chunk rode a wave. `a` = chunk tokens, `b` = prompt
    /// tokens prefilled so far.
    PrefillChunk,
    /// Span: one device forward (decode/mixed/verify wave). `a` = bucket,
    /// `b` = rows; `link_us`/`energy_j` carry the modeled link time and
    /// wave energy.
    Wave,
    /// Span: modeled per-stage slice of a wave (pipelined engines only).
    /// `a` = stage index.
    StageSpan,
    /// Draft proposed a chain. `a` = proposed tokens.
    SpecPropose,
    /// Verify wave accepted a prefix. `a` = accepted, `b` = proposed.
    SpecAccept,
    /// Verify wave rolled back rejected rows. `a` = rejected tokens.
    SpecRollback,
    /// Committed tokens attributed to one wave. `a` = token count.
    Tokens,
    /// Periodic decode checkpoint. `a` = checkpoints carried.
    Checkpoint,
    /// Request state left this cartridge. `a` = by-value KV rows,
    /// `b` = by-ref rows.
    Export,
    /// Request state restored on this cartridge. `a` = by-value KV rows,
    /// `b` = by-ref rows.
    Resume,
    /// Fleet moved the request. `a` = source cartridge, `b` = target.
    Migrate,
    /// Request finished. `a` = tokens generated, `b` = reported E2E (µs).
    Complete,
    /// Client cancelled (or its stream disconnected). Recorded by the
    /// dispatcher when the cancel lands. `a` = 1 if the request was already
    /// placed on a cartridge, 0 if it was still queued.
    Cancel,
    /// Admission control rejected the request before it ever queued.
    /// `a` = projected queue wait (µs), `b` = the SLO budget it exceeded
    /// (µs). `req` is the *client* id — a shed request never gets a ticket.
    Shed,
    /// A cancel reached the scheduler mid-flight: the request's rows were
    /// evicted and its KV pages freed. `a` = tokens generated at eviction,
    /// `b` = KV rows freed.
    Preempt,
    /// The KV budget paged this sequence out to the disk spill tier.
    /// `a` = KV rows spilled, `b` = spill-file bytes.
    Spill,
    /// The sequence's KV was restored from the spill tier ahead of its
    /// next decode step. `a` = KV rows restored, `b` = spill-file bytes.
    Unspill,
    /// An SLO burn-rate alert transitioned (see
    /// `coordinator::telemetry`). `a` = SLO id (0 = `itl_p99`,
    /// 1 = `availability`), `b` = 1 on fire / 0 on clear. Not tied to a
    /// request ([`REQ_NONE`]); renders on the control track.
    Alert,
}

impl TraceKind {
    /// Stable lowercase name (trace JSON `name` field; pinned by tests and
    /// the `trace_check` schema checker).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Admit => "admit",
            TraceKind::Queued => "queued",
            TraceKind::Active => "active",
            TraceKind::PrefillChunk => "prefill_chunk",
            TraceKind::Wave => "wave",
            TraceKind::StageSpan => "stage",
            TraceKind::SpecPropose => "spec_propose",
            TraceKind::SpecAccept => "spec_accept",
            TraceKind::SpecRollback => "spec_rollback",
            TraceKind::Tokens => "tokens",
            TraceKind::Checkpoint => "checkpoint",
            TraceKind::Export => "export",
            TraceKind::Resume => "resume",
            TraceKind::Migrate => "migrate",
            TraceKind::Complete => "complete",
            TraceKind::Cancel => "cancel",
            TraceKind::Shed => "shed",
            TraceKind::Preempt => "preempt",
            TraceKind::Spill => "spill",
            TraceKind::Unspill => "unspill",
            TraceKind::Alert => "alert",
        }
    }

    /// Span kinds render as Perfetto duration events (`ph: "X"`); the rest
    /// are thread-scoped instants (`ph: "i"`).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            TraceKind::Queued | TraceKind::Active | TraceKind::Wave | TraceKind::StageSpan
        )
    }
}

/// One recorded event. Flat and `Copy` so the ring never allocates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// µs since the trace epoch.
    pub ts_us: u64,
    /// Span duration in µs (0 for instants).
    pub dur_us: u64,
    pub kind: TraceKind,
    /// Wire ticket (fleet-unique), or [`REQ_NONE`].
    pub req: u64,
    /// Stamped by the fleet dispatcher when it absorbs worker events.
    pub cartridge: u32,
    /// Wave sequence number within the recording scheduler, or
    /// [`WAVE_NONE`].
    pub wave: u64,
    /// Kind-specific operand (see [`TraceKind`]).
    pub a: u64,
    /// Kind-specific operand (see [`TraceKind`]).
    pub b: u64,
    /// Modeled link-transfer share of a wave span (µs).
    pub link_us: u64,
    /// Modeled device energy of a wave span (joules).
    pub energy_j: f64,
}

impl TraceEvent {
    /// An instant of `kind` at `ts_us` with all operands zeroed/none.
    pub fn at(ts_us: u64, kind: TraceKind) -> TraceEvent {
        TraceEvent {
            ts_us,
            dur_us: 0,
            kind,
            req: REQ_NONE,
            cartridge: 0,
            wave: WAVE_NONE,
            a: 0,
            b: 0,
            link_us: 0,
            energy_j: 0.0,
        }
    }
}

/// Ring-buffered per-scheduler event recorder. One per scheduler, drained
/// into checkpoint reports by the worker loop; never shared across threads,
/// so recording is a branch plus a `VecDeque` push.
pub struct TraceRecorder {
    enabled: bool,
    epoch: Instant,
    ring: VecDeque<TraceEvent>,
    capacity: usize,
    dropped: u64,
}

impl TraceRecorder {
    /// The no-op recorder: [`TraceRecorder::enabled`] is false,
    /// [`TraceRecorder::record`] discards.
    pub fn disabled() -> TraceRecorder {
        TraceRecorder {
            enabled: false,
            epoch: Instant::now(),
            ring: VecDeque::new(),
            capacity: 0,
            dropped: 0,
        }
    }

    /// An enabled recorder holding at most `capacity` events, stamping
    /// timestamps relative to `epoch`.
    pub fn new(capacity: usize, epoch: Instant) -> TraceRecorder {
        TraceRecorder {
            enabled: capacity > 0,
            epoch,
            ring: VecDeque::with_capacity(capacity.min(4096)),
            capacity,
            dropped: 0,
        }
    }

    /// Gate every instrumentation site on this — it inlines to a bool load,
    /// which is the entire disabled-path cost.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// µs since the trace epoch, now.
    pub fn now_us(&self) -> u64 {
        self.ts_us(Instant::now())
    }

    /// µs since the trace epoch at `at` (0 if `at` predates the epoch).
    pub fn ts_us(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Push an event; drops (and counts) the oldest when the ring is full.
    pub fn record(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.ring.len() >= self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    /// Take everything recorded since the last drain.
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        self.ring.drain(..).collect()
    }

    /// Events lost to ring overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Return and reset the overflow-drop counter (drained alongside the
    /// events, so checkpoint reports carry per-interval deltas).
    pub fn take_dropped(&mut self) -> u64 {
        std::mem::take(&mut self.dropped)
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

/// One request's full event chain, reconstructed from the merged fleet
/// timeline (flight-recorder unit).
#[derive(Debug, Clone)]
pub struct RequestChain {
    /// Wire ticket.
    pub req: u64,
    /// Reported E2E latency (µs) from the `Complete` event, or the chain's
    /// timestamp extent if the request never completed.
    pub total_us: u64,
    /// The request's events, in timestamp order.
    pub events: Vec<TraceEvent>,
}

/// The merged, cartridge-stamped event timeline a fleet shutdown returns
/// (see `Fleet::shutdown_traced`), with the exporters on top.
#[derive(Debug, Clone, Default)]
pub struct FleetTrace {
    /// All events, sorted by timestamp.
    pub events: Vec<TraceEvent>,
    /// Events lost to ring/sink overflow across all cartridges.
    pub dropped: u64,
}

impl FleetTrace {
    /// Build from raw events (sorts by timestamp, then wave/kind for a
    /// stable order at equal timestamps).
    pub fn new(mut events: Vec<TraceEvent>, dropped: u64) -> FleetTrace {
        events.sort_by_key(|e| (e.ts_us, e.cartridge, e.wave, e.req));
        FleetTrace { events, dropped }
    }

    /// Chrome/Perfetto `trace_events` JSON: load the string (written to a
    /// file) at <https://ui.perfetto.dev> or `chrome://tracing`. One process
    /// per cartridge; per cartridge one `waves` track, one track per
    /// pipeline stage, a `control` track (checkpoints/migrations), and one
    /// track per request carrying its lifecycle chain.
    pub fn perfetto_json(&self) -> String {
        const TID_WAVES: u64 = 0;
        const TID_STAGE_BASE: u64 = 1; // + stage index
        const TID_CONTROL: u64 = 90;
        const TID_REQ_BASE: u64 = 100; // + wire ticket

        let mut out: Vec<String> = Vec::with_capacity(self.events.len() + 16);
        // (pid, tid) -> track name, emitted as metadata events up front
        let mut tracks: Vec<(u32, u64, String)> = Vec::new();
        let mut track_seen = std::collections::HashSet::new();
        let mut pids = std::collections::HashSet::new();

        for ev in &self.events {
            let pid = ev.cartridge;
            let (tid, track_name) = match ev.kind {
                TraceKind::Wave => (TID_WAVES, "waves".to_string()),
                TraceKind::StageSpan => {
                    (TID_STAGE_BASE + ev.a, format!("stage {}", ev.a))
                }
                TraceKind::Checkpoint | TraceKind::Migrate | TraceKind::Shed
                | TraceKind::Alert => (TID_CONTROL, "control".to_string()),
                _ => (TID_REQ_BASE + ev.req, format!("req {}", ev.req)),
            };
            pids.insert(pid);
            if track_seen.insert((pid, tid)) {
                tracks.push((pid, tid, track_name));
            }

            let mut j = Json::default();
            j.str("name", ev.kind.name());
            if ev.kind.is_span() {
                j.str("ph", "X");
                j.num("dur", ev.dur_us.max(1));
            } else {
                j.str("ph", "i");
                j.str("s", "t");
            }
            j.num("pid", pid);
            j.num("tid", tid);
            j.num("ts", ev.ts_us);
            j.str("cat", "ita");
            j.put("args", Self::args_json(ev));
            out.push(j.encode());
        }

        // metadata events so Perfetto labels the tracks
        let mut meta: Vec<String> = Vec::new();
        let mut pid_list: Vec<u32> = pids.into_iter().collect();
        pid_list.sort_unstable();
        for pid in pid_list {
            let mut j = Json::default();
            j.str("name", "process_name");
            j.str("ph", "M");
            j.num("pid", pid);
            let mut args = Json::default();
            args.str("name", &format!("cartridge {pid}"));
            j.put("args", args.encode());
            meta.push(j.encode());
        }
        tracks.sort_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        for (pid, tid, name) in tracks {
            let mut j = Json::default();
            j.str("name", "thread_name");
            j.str("ph", "M");
            j.num("pid", pid);
            j.num("tid", tid);
            let mut args = Json::default();
            args.str("name", &name);
            j.put("args", args.encode());
            meta.push(j.encode());
        }
        meta.extend(out);

        let mut root = Json::default();
        root.put("traceEvents", json_array(&meta));
        root.str("displayTimeUnit", "ms");
        root.num("ita_dropped_events", self.dropped);
        root.encode()
    }

    fn args_json(ev: &TraceEvent) -> String {
        let mut args = Json::default();
        if ev.req != REQ_NONE {
            args.num("req", ev.req);
        }
        if ev.wave != WAVE_NONE {
            args.num("wave", ev.wave);
        }
        match ev.kind {
            TraceKind::Admit => {
                args.num("queue_wait_us", ev.a).num("prompt_tokens", ev.b);
            }
            TraceKind::Queued => {}
            TraceKind::Active => {
                args.num("tokens", ev.a);
            }
            TraceKind::PrefillChunk => {
                args.num("tokens", ev.a).num("prefilled", ev.b);
            }
            TraceKind::Wave => {
                args.num("bucket", ev.a)
                    .num("rows", ev.b)
                    .num("link_us", ev.link_us)
                    .float("energy_uj", ev.energy_j * 1e6);
            }
            TraceKind::StageSpan => {
                args.num("stage", ev.a);
            }
            TraceKind::SpecPropose => {
                args.num("proposed", ev.a);
            }
            TraceKind::SpecAccept => {
                args.num("accepted", ev.a).num("proposed", ev.b);
            }
            TraceKind::SpecRollback => {
                args.num("rejected", ev.a);
            }
            TraceKind::Tokens => {
                args.num("count", ev.a);
            }
            TraceKind::Checkpoint => {
                args.num("decode_ckpts", ev.a);
            }
            TraceKind::Export | TraceKind::Resume => {
                args.num("rows", ev.a).num("by_ref", ev.b);
            }
            TraceKind::Migrate => {
                args.num("from", ev.a).num("to", ev.b);
            }
            TraceKind::Complete => {
                args.num("tokens", ev.a).num("total_us", ev.b);
            }
            TraceKind::Cancel => {
                args.num("in_flight", ev.a);
            }
            TraceKind::Shed => {
                args.num("projected_wait_us", ev.a).num("slo_budget_us", ev.b);
            }
            TraceKind::Preempt => {
                args.num("tokens", ev.a).num("kv_rows_freed", ev.b);
            }
            TraceKind::Spill | TraceKind::Unspill => {
                args.num("rows", ev.a).num("bytes", ev.b);
            }
            TraceKind::Alert => {
                args.str("slo", if ev.a == 0 { "itl_p99" } else { "availability" })
                    .bool("firing", ev.b == 1);
            }
        }
        args.encode()
    }

    /// Group the timeline into per-request chains, slowest first.
    pub fn request_chains(&self) -> Vec<RequestChain> {
        let mut by_req: std::collections::HashMap<u64, Vec<TraceEvent>> =
            std::collections::HashMap::new();
        for ev in &self.events {
            if ev.req != REQ_NONE {
                by_req.entry(ev.req).or_default().push(*ev);
            }
        }
        let mut chains: Vec<RequestChain> = by_req
            .into_iter()
            .map(|(req, events)| {
                let total_us = events
                    .iter()
                    .find(|e| e.kind == TraceKind::Complete)
                    .map(|e| e.b)
                    .unwrap_or_else(|| {
                        let lo = events.iter().map(|e| e.ts_us).min().unwrap_or(0);
                        let hi = events
                            .iter()
                            .map(|e| e.ts_us + e.dur_us)
                            .max()
                            .unwrap_or(0);
                        hi.saturating_sub(lo)
                    });
                RequestChain { req, total_us, events }
            })
            .collect();
        chains.sort_by_key(|c| (std::cmp::Reverse(c.total_us), c.req));
        chains
    }

    /// Flight-recorder dump: the `n` slowest requests with their full event
    /// chains, as a standalone JSON document.
    pub fn flight_recorder(&self, n: usize) -> String {
        let chains: Vec<String> = self
            .request_chains()
            .into_iter()
            .take(n)
            .map(|c| {
                let events: Vec<String> = c
                    .events
                    .iter()
                    .map(|e| {
                        let mut j = Json::default();
                        j.num("ts_us", e.ts_us);
                        j.str("kind", e.kind.name());
                        if e.dur_us > 0 {
                            j.num("dur_us", e.dur_us);
                        }
                        j.num("cartridge", e.cartridge);
                        if e.wave != WAVE_NONE {
                            j.num("wave", e.wave);
                        }
                        j.num("a", e.a);
                        j.num("b", e.b);
                        j.encode()
                    })
                    .collect();
                let mut j = Json::default();
                j.num("req", c.req);
                j.num("total_us", c.total_us);
                j.put("events", json_array(&events));
                j.encode()
            })
            .collect();
        let mut root = Json::default();
        root.put("slowest", json_array(&chains));
        root.num("dropped_events", self.dropped);
        root.encode()
    }
}

// ---------------------------------------------------------------------------
// tail-based sampling
// ---------------------------------------------------------------------------

/// Retention policy for [`TailSampler`].
#[derive(Debug, Clone, Copy)]
pub struct TailSamplerOpts {
    /// Hard cap on retained events across all chains and the ambient
    /// ring. Evictions count into `dropped`.
    pub budget_events: usize,
    /// Keep the `k` slowest completed chains (by reported E2E latency).
    pub slow_k: usize,
    /// Head-sample one in `n` of the remaining completed chains (ticket
    /// modulo), preserving an unbiased cross-section of normal traffic.
    /// 0 disables head sampling.
    pub head_every: u64,
}

impl Default for TailSamplerOpts {
    fn default() -> Self {
        TailSamplerOpts { budget_events: 1 << 14, slow_k: 8, head_every: 64 }
    }
}

/// Why a completed chain was retained. Eviction under budget pressure
/// prefers the least interesting reason first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum KeepReason {
    /// Head-sampled cross-section (first to go under pressure).
    Head,
    /// Among the top-k slowest.
    Slow,
    /// Shed, cancelled, preempted, migrated, or requeued — the outliers
    /// tail sampling exists to keep (last to go).
    Flagged,
}

#[derive(Debug)]
struct SampledChain {
    score: u64,
    reason: KeepReason,
    events: Vec<TraceEvent>,
}

/// Tail-based trace sampling: buffers each request's event chain until it
/// completes, then keeps the chain only if the request was *interesting*
/// — shed, cancelled, preempted, migrated, or requeued (flagged on sight
/// of the corresponding events), among the top-k slowest, or head-sampled
/// — all under a hard event budget. This is what makes always-on tracing
/// production-viable: memory is bounded by policy, not by traffic, and
/// the events worth a post-incident look are exactly the ones retained.
///
/// Events not tied to a request (wave/stage spans, checkpoints, alerts)
/// go to a bounded ambient ring so the timeline keeps its utilization
/// context without unbounded growth.
#[derive(Debug)]
pub struct TailSampler {
    opts: TailSamplerOpts,
    /// In-flight chains: ticket → (flagged, events).
    open: std::collections::HashMap<u64, (bool, Vec<TraceEvent>)>,
    open_events: usize,
    kept: Vec<SampledChain>,
    kept_events: usize,
    ambient: VecDeque<TraceEvent>,
    ambient_cap: usize,
    dropped: u64,
}

impl TailSampler {
    pub fn new(opts: TailSamplerOpts) -> TailSampler {
        TailSampler {
            opts,
            open: std::collections::HashMap::new(),
            open_events: 0,
            kept: Vec::new(),
            kept_events: 0,
            ambient: VecDeque::new(),
            ambient_cap: (opts.budget_events / 4).max(16),
            dropped: 0,
        }
    }

    /// Events lost to sampling decisions and budget evictions so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events currently retained (open + kept + ambient).
    pub fn retained(&self) -> usize {
        self.open_events + self.kept_events + self.ambient.len()
    }

    /// Offer one event to the sampler; it is either buffered, retained,
    /// or dropped-and-counted according to the retention policy.
    pub fn offer(&mut self, ev: TraceEvent) {
        if ev.req == REQ_NONE {
            if self.ambient.len() >= self.ambient_cap {
                self.ambient.pop_front();
                self.dropped += 1;
            }
            self.ambient.push_back(ev);
            return;
        }
        // dispatcher-side shed/cancel instants are keyed by *client* id
        // (a shed request never gets a ticket) and arrive as standalone
        // chains: always retain
        if matches!(ev.kind, TraceKind::Shed | TraceKind::Cancel) {
            self.kept_events += 1;
            self.kept.push(SampledChain {
                score: u64::MAX,
                reason: KeepReason::Flagged,
                events: vec![ev],
            });
            self.enforce_budget();
            return;
        }
        let entry = self.open.entry(ev.req).or_insert_with(|| (false, Vec::new()));
        if matches!(
            ev.kind,
            TraceKind::Export | TraceKind::Resume | TraceKind::Preempt | TraceKind::Migrate
        ) {
            entry.0 = true;
        }
        entry.1.push(ev);
        self.open_events += 1;
        if ev.kind == TraceKind::Complete {
            let (flagged, events) = self.open.remove(&ev.req).expect("chain just touched");
            self.open_events -= events.len();
            self.close(ev.req, ev.b, flagged, events);
        } else if self.open_events > self.opts.budget_events {
            // runaway open set (chains that never complete): shed the
            // largest un-flagged chain, or the largest outright
            let victim = self
                .open
                .iter()
                .min_by_key(|(_, (flagged, v))| (*flagged, std::cmp::Reverse(v.len())))
                .map(|(k, _)| *k);
            if let Some(k) = victim {
                let (_, v) = self.open.remove(&k).expect("victim exists");
                self.open_events -= v.len();
                self.dropped += v.len() as u64;
            }
        }
    }

    /// Completed-chain retention decision.
    fn close(&mut self, req: u64, score: u64, flagged: bool, events: Vec<TraceEvent>) {
        let reason = if flagged {
            Some(KeepReason::Flagged)
        } else if self.opts.head_every > 0 && req % self.opts.head_every == 0 {
            Some(KeepReason::Head)
        } else if self.qualifies_slow(score) {
            Some(KeepReason::Slow)
        } else {
            None
        };
        match reason {
            None => self.dropped += events.len() as u64,
            Some(reason) => {
                self.kept_events += events.len();
                self.kept.push(SampledChain { score, reason, events });
                if reason == KeepReason::Slow {
                    self.prune_slow();
                }
                self.enforce_budget();
            }
        }
    }

    fn qualifies_slow(&self, score: u64) -> bool {
        let slow: Vec<u64> = self
            .kept
            .iter()
            .filter(|c| c.reason == KeepReason::Slow)
            .map(|c| c.score)
            .collect();
        slow.len() < self.opts.slow_k || slow.iter().any(|&s| score > s)
    }

    /// Keep only the k slowest among `Slow`-retained chains.
    fn prune_slow(&mut self) {
        loop {
            let slow_count =
                self.kept.iter().filter(|c| c.reason == KeepReason::Slow).count();
            if slow_count <= self.opts.slow_k {
                return;
            }
            let victim = self
                .kept
                .iter()
                .enumerate()
                .filter(|(_, c)| c.reason == KeepReason::Slow)
                .min_by_key(|(_, c)| c.score)
                .map(|(i, _)| i)
                .expect("slow_count > 0");
            let chain = self.kept.remove(victim);
            self.kept_events -= chain.events.len();
            self.dropped += chain.events.len() as u64;
        }
    }

    /// Hard budget: evict kept chains least-interesting-first (`Head`,
    /// then fastest `Slow`, then oldest `Flagged`), then ambient events.
    fn enforce_budget(&mut self) {
        while self.retained() > self.opts.budget_events {
            let victim = self
                .kept
                .iter()
                .enumerate()
                .min_by_key(|(i, c)| (c.reason, c.score, *i))
                .map(|(i, _)| i);
            if let Some(i) = victim {
                let chain = self.kept.remove(i);
                self.kept_events -= chain.events.len();
                self.dropped += chain.events.len() as u64;
            } else if self.ambient.pop_front().is_some() {
                self.dropped += 1;
            } else {
                return; // only open chains remain; offer() bounds those
            }
        }
    }

    /// All retained events (ambient + kept + still-open chains) and the
    /// total drop count, consumed at fleet shutdown.
    pub fn finish(self) -> (Vec<TraceEvent>, u64) {
        let mut events: Vec<TraceEvent> = self.ambient.into_iter().collect();
        for chain in self.kept {
            events.extend(chain.events);
        }
        for (_, (_, chain)) in self.open {
            events.extend(chain);
        }
        (events, self.dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{parse, JsonValue};

    #[test]
    fn disabled_recorder_discards_for_free() {
        let mut t = TraceRecorder::disabled();
        assert!(!t.enabled());
        t.record(TraceEvent::at(1, TraceKind::Admit));
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_caps_and_counts_drops() {
        let mut t = TraceRecorder::new(2, Instant::now());
        assert!(t.enabled());
        for i in 0..5u64 {
            t.record(TraceEvent::at(i, TraceKind::Wave));
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
        let evs = t.drain();
        assert_eq!(evs[0].ts_us, 3);
        assert_eq!(evs[1].ts_us, 4);
        assert!(t.is_empty());
    }

    #[test]
    fn perfetto_export_is_valid_json_with_tracks() {
        let mut wave = TraceEvent::at(10, TraceKind::Wave);
        wave.dur_us = 5;
        wave.wave = 1;
        wave.a = 4;
        wave.b = 3;
        wave.energy_j = 1e-6;
        let mut complete = TraceEvent::at(20, TraceKind::Complete);
        complete.req = 0;
        complete.a = 7;
        complete.b = 19;
        let trace = FleetTrace::new(vec![complete, wave], 0);
        // sorted by ts: wave first
        assert_eq!(trace.events[0].kind, TraceKind::Wave);
        let doc = parse(&trace.perfetto_json()).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(JsonValue::as_array).expect("array");
        // 2 events + process_name + 2 thread_name metadata
        assert_eq!(events.len(), 5);
        let wave_ev = events
            .iter()
            .find(|e| e.get("name").and_then(JsonValue::as_str) == Some("wave"))
            .expect("wave event");
        assert_eq!(wave_ev.get("ph").and_then(JsonValue::as_str), Some("X"));
        assert_eq!(wave_ev.get("dur").and_then(JsonValue::as_f64), Some(5.0));
        let args = wave_ev.get("args").expect("args");
        assert_eq!(args.get("bucket").and_then(JsonValue::as_f64), Some(4.0));
    }

    #[test]
    fn flight_recorder_ranks_slowest_first() {
        let mut fast = TraceEvent::at(5, TraceKind::Complete);
        fast.req = 1;
        fast.b = 100;
        let mut slow = TraceEvent::at(9, TraceKind::Complete);
        slow.req = 2;
        slow.b = 900;
        let trace = FleetTrace::new(vec![fast, slow], 0);
        let chains = trace.request_chains();
        assert_eq!(chains[0].req, 2);
        assert_eq!(chains[0].total_us, 900);
        let doc = parse(&trace.flight_recorder(1)).expect("valid JSON");
        let slowest = doc.get("slowest").and_then(JsonValue::as_array).expect("array");
        assert_eq!(slowest.len(), 1);
        assert_eq!(slowest[0].get("req").and_then(JsonValue::as_f64), Some(2.0));
    }

    /// A minimal admit→complete chain for request `req` with reported E2E
    /// latency `total_us`.
    fn chain(req: u64, total_us: u64) -> Vec<TraceEvent> {
        let mut admit = TraceEvent::at(1, TraceKind::Admit);
        admit.req = req;
        let mut complete = TraceEvent::at(1 + total_us, TraceKind::Complete);
        complete.req = req;
        complete.b = total_us;
        vec![admit, complete]
    }

    #[test]
    fn tail_sampler_keeps_flagged_and_slow_chains_drops_the_rest() {
        let opts = TailSamplerOpts { budget_events: 1 << 10, slow_k: 2, head_every: 0 };
        let mut s = TailSampler::new(opts);
        // 20 unremarkable fast chains (odd tickets so head sampling — even
        // disabled here — can't save them), one slow outlier, one preempted
        for i in 0..20u64 {
            for ev in chain(2 * i + 1, 100 + i) {
                s.offer(ev);
            }
        }
        for ev in chain(101, 90_000) {
            s.offer(ev);
        }
        let mut preempt = TraceEvent::at(5, TraceKind::Preempt);
        preempt.req = 103;
        s.offer(preempt);
        let mut complete = TraceEvent::at(6, TraceKind::Complete);
        complete.req = 103;
        complete.b = 1; // fastest of all — retained anyway, it was flagged
        s.offer(complete);

        let dropped_before = s.dropped();
        assert!(dropped_before > 0, "unremarkable chains must be dropped");
        let (events, dropped) = s.finish();
        assert_eq!(dropped, dropped_before);
        let reqs: std::collections::HashSet<u64> =
            events.iter().map(|e| e.req).filter(|&r| r != REQ_NONE).collect();
        assert!(reqs.contains(&101), "slowest chain retained");
        assert!(reqs.contains(&103), "preempted chain retained");
        // the slow outlier displaced one of the two previously-slowest
        // unremarkable chains (scores 118, 119): only the slower survives
        assert!(reqs.contains(&39), "top-k slowest retained: {reqs:?}");
        assert!(!reqs.contains(&37), "displaced from top-k by the outlier: {reqs:?}");
        assert!(!reqs.contains(&1), "fast unflagged chain sampled away");
    }

    #[test]
    fn tail_sampler_head_samples_a_cross_section() {
        let opts = TailSamplerOpts { budget_events: 1 << 10, slow_k: 0, head_every: 8 };
        let mut s = TailSampler::new(opts);
        for i in 0..32u64 {
            for ev in chain(i, 100) {
                s.offer(ev);
            }
        }
        let (events, _) = s.finish();
        let reqs: std::collections::HashSet<u64> =
            events.iter().map(|e| e.req).collect();
        assert_eq!(reqs, [0u64, 8, 16, 24].into_iter().collect());
    }

    #[test]
    fn tail_sampler_enforces_the_event_budget() {
        let opts = TailSamplerOpts { budget_events: 8, slow_k: 64, head_every: 0 };
        let mut s = TailSampler::new(opts);
        // every chain qualifies as "slow" (slow_k is huge) but the hard
        // budget caps retention anyway
        for i in 0..50u64 {
            for ev in chain(i, 100 + i) {
                s.offer(ev);
            }
        }
        assert!(s.retained() <= 8, "budget violated: {} events", s.retained());
        // shed instants (flagged) survive budget pressure at the expense
        // of slow chains
        let mut shed = TraceEvent::at(9, TraceKind::Shed);
        shed.req = 999;
        s.offer(shed);
        let (events, dropped) = s.finish();
        assert!(events.iter().any(|e| e.kind == TraceKind::Shed));
        assert!(dropped >= 92, "evictions counted: {dropped}");
    }

    #[test]
    fn tail_sampler_bounds_ambient_and_open_sets() {
        let opts = TailSamplerOpts { budget_events: 16, slow_k: 4, head_every: 0 };
        let mut s = TailSampler::new(opts);
        for i in 0..100u64 {
            let mut wave = TraceEvent::at(i, TraceKind::Wave);
            wave.dur_us = 1;
            s.offer(wave); // req = REQ_NONE → ambient ring
        }
        assert!(s.retained() <= 16);
        // chains that never complete can't pin unbounded memory either
        for i in 0..100u64 {
            let mut admit = TraceEvent::at(i, TraceKind::Admit);
            admit.req = i;
            s.offer(admit);
        }
        assert!(s.retained() <= 2 * 16, "open set unbounded: {}", s.retained());
        assert!(s.dropped() > 0);
    }
}
