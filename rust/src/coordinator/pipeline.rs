//! Pipeline-parallel cartridge sharding (ROADMAP item 1; Cambricon-LLM in
//! PAPERS.md): a model larger than one fixed-weight die is served by K
//! stage-cartridges, each burned with a contiguous run of layers, with the
//! INT16 hidden state streaming stage → stage over a host-priced [`Link`].
//!
//! [`PipelineEngine`] is the *builder*: it partitions a model's layers
//! across K simulated stage devices and assembles them into the ordinary
//! [`Engine`] via [`Engine::sharded`] — the scheduler, fleet, spec-decode,
//! and migration layers see the same `Engine` type they always did, so a
//! pipeline group IS one logical cartridge to everything above it.
//!
//! The safety rail is the repo's differential discipline:
//! * K=1 is byte-identical to [`Engine::synthetic`] by construction (same
//!   weight stream, same code path, no link hops);
//! * any K is byte-identical to K=1, because stage handoff is exact in the
//!   simulation (the link only accrues modeled cost) and every layer sees
//!   the same hidden state and the same own-stage KV it would have seen
//!   unsharded. Pinned in `rust/tests/pipeline_sim.rs`.

use std::ops::Range;

use crate::config::ModelConfig;
use crate::coordinator::engine::Engine;
use crate::device::sim::SimDevice;
use crate::device::{DeviceDims, ItaDevice};
use crate::host::embedding::EmbeddingTable;
use crate::interface::link::Link;
use crate::model::ModelWeights;

/// Balanced contiguous partition of `n_layers` layers into `k` stages:
/// the first `n_layers % k` stages take one extra layer. Every layer is
/// covered exactly once, in order.
pub fn partition_layers(n_layers: usize, k: usize) -> Vec<Range<usize>> {
    assert!(k >= 1, "pipeline needs at least one stage");
    assert!(k <= n_layers, "more stages ({k}) than layers ({n_layers})");
    let base = n_layers / k;
    let extra = n_layers % k;
    let mut out = Vec::with_capacity(k);
    let mut at = 0;
    for s in 0..k {
        let take = base + usize::from(s < extra);
        out.push(at..at + take);
        at += take;
    }
    debug_assert_eq!(at, n_layers);
    out
}

/// Split one wave's measured duration into K modeled per-stage
/// `(offset_us, dur_us)` slices for the trace timeline: compute time is
/// apportioned proportionally to each stage's layer count, and the wave's
/// modeled link time (`link_us`, capped at the wave duration) is spread
/// evenly across the K−1 inter-stage gaps. Offsets are relative to the
/// wave's start.
///
/// These slices are *modeled*, like stage occupancy: the sim executes
/// stages sequentially inside one `forward`, so the trace shows where the
/// time would go on physical stage dies, not separately-measured spans.
pub fn stage_spans(dur_us: u64, link_us: u64, layers: &[usize]) -> Vec<(u64, u64)> {
    assert!(!layers.is_empty(), "stage_spans needs at least one stage");
    let k = layers.len();
    let total_layers: usize = layers.iter().sum::<usize>().max(1);
    let hops = (k - 1) as u64;
    let link_total = link_us.min(dur_us);
    let compute = dur_us - link_total;
    let gap = if hops > 0 { link_total / hops } else { 0 };
    let mut out = Vec::with_capacity(k);
    let mut at = 0u64;
    for (s, &l) in layers.iter().enumerate() {
        let d = (compute as u128 * l as u128 / total_layers as u128) as u64;
        out.push((at, d.max(1)));
        at += d;
        if s + 1 < k {
            at += gap;
        }
    }
    out
}

/// Builder for a pipeline-sharded [`Engine`] over simulated stage devices.
///
/// ```no_run
/// use ita::config::ModelConfig;
/// use ita::coordinator::pipeline::PipelineEngine;
/// use ita::interface::link::Link;
/// let engine = PipelineEngine::new(2).link(Link::tb4())
///     .synthetic(&ModelConfig::TINY, 7);
/// assert_eq!(engine.n_stages(), 2);
/// ```
#[derive(Debug, Clone)]
pub struct PipelineEngine {
    stages: usize,
    link: Link,
    buckets: Vec<usize>,
}

impl PipelineEngine {
    /// A K-stage pipeline over [`Link::pcie3_x4`] with the standard
    /// `[1, 2, 4, 8]` batch buckets ([`Engine::synthetic`]'s defaults).
    pub fn new(stages: usize) -> PipelineEngine {
        assert!(stages >= 1, "pipeline needs at least one stage");
        PipelineEngine { stages, link: Link::pcie3_x4(), buckets: vec![1, 2, 4, 8] }
    }

    /// Override the inter-stage activation link.
    pub fn link(mut self, link: Link) -> PipelineEngine {
        self.link = link;
        self
    }

    /// Override the compiled batch buckets (every stage gets the same set).
    pub fn buckets(mut self, buckets: Vec<usize>) -> PipelineEngine {
        assert!(!buckets.is_empty());
        self.buckets = buckets;
        self
    }

    /// Build the sharded engine over synthetic weights. The full weight set
    /// is generated ONCE from `(cfg, seed)` — exactly the stream
    /// [`Engine::synthetic`] draws — and each stage device receives its
    /// contiguous layer slice of it, so stage s runs bit-identical
    /// arithmetic to layers `partition_layers(..)[s]` of the unsharded
    /// engine. K=1 therefore *is* the plain synthetic engine.
    pub fn synthetic(&self, cfg: &ModelConfig, seed: u64) -> Engine {
        let full = ModelWeights::synthetic(cfg, seed);
        let emb = EmbeddingTable::new(full.emb.clone());
        let parts = partition_layers(cfg.n_layers, self.stages);
        let mut devices: Vec<Box<dyn ItaDevice>> = Vec::with_capacity(self.stages);
        let mut layers = full.layers.into_iter();
        for range in &parts {
            let stage_weights = ModelWeights {
                layers: layers.by_ref().take(range.len()).collect(),
                gf: full.gf.clone(),
                we: full.we.clone(),
                emb: full.emb.clone(),
            };
            let dims = DeviceDims {
                d_model: cfg.d_model,
                n_layers: range.len(),
                d_ffn: cfg.d_ffn,
                vocab: cfg.vocab,
            };
            devices.push(Box::new(SimDevice::from_weights(
                dims,
                stage_weights,
                self.buckets.clone(),
            )));
        }
        Engine::sharded(devices, emb, cfg.n_heads, self.link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::tokenizer::ByteTokenizer;

    #[test]
    fn partition_is_balanced_contiguous_and_total() {
        assert_eq!(partition_layers(4, 1), vec![0..4]);
        assert_eq!(partition_layers(4, 2), vec![0..2, 2..4]);
        assert_eq!(partition_layers(4, 4), vec![0..1, 1..2, 2..3, 3..4]);
        assert_eq!(partition_layers(5, 2), vec![0..3, 3..5]);
        assert_eq!(partition_layers(7, 3), vec![0..3, 3..5, 5..7]);
        for (n, k) in [(1, 1), (13, 5), (32, 4), (40, 7)] {
            let parts = partition_layers(n, k);
            assert_eq!(parts.len(), k);
            let mut at = 0;
            for p in &parts {
                assert_eq!(p.start, at, "contiguous");
                assert!(!p.is_empty(), "no empty stage");
                at = p.end;
            }
            assert_eq!(at, n, "covers all layers");
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced: {sizes:?}");
        }
    }

    #[test]
    #[should_panic]
    fn partition_rejects_more_stages_than_layers() {
        partition_layers(2, 3);
    }

    #[test]
    fn stage_spans_are_ordered_proportional_and_bounded() {
        // 3 layers + 1 layer over a 900 µs wave with 100 µs of link time:
        // two stages, one 50 µs gap each side of ... actually one gap
        let spans = stage_spans(900, 100, &[3, 1]);
        assert_eq!(spans.len(), 2);
        let (o0, d0) = spans[0];
        let (o1, d1) = spans[1];
        assert_eq!(o0, 0);
        // compute = 800 µs split 3:1
        assert_eq!(d0, 600);
        assert_eq!(d1, 200);
        // stage 1 starts after stage 0 plus the link gap
        assert_eq!(o1, 600 + 100);
        assert!(o1 + d1 <= 900, "spans stay inside the wave");
        // degenerate cases: single stage spans the whole compute time;
        // link time larger than the wave clamps instead of underflowing
        assert_eq!(stage_spans(50, 0, &[4]), vec![(0, 50)]);
        let clamped = stage_spans(10, 10_000, &[1, 1]);
        assert_eq!(clamped.len(), 2);
        assert!(clamped.iter().all(|&(o, d)| o + d <= 10 + 10_000));
        // zero-duration wave still yields non-zero (1 µs floor) spans
        assert!(stage_spans(0, 0, &[1, 1]).iter().all(|&(_, d)| d >= 1));
    }

    #[test]
    fn k1_pipeline_is_plain_synthetic_engine() {
        let cfg = ModelConfig::TINY;
        let toks = ByteTokenizer::new().encode("pipeline k=1");
        let mut plain = Engine::synthetic(&cfg, 11);
        let mut piped = PipelineEngine::new(1).synthetic(&cfg, 11);
        assert_eq!(piped.n_stages(), 1);
        assert_eq!(piped.dims(), plain.dims());
        let sa = plain.new_sequence();
        let sb = piped.new_sequence();
        let la = plain.prefill(sa, &toks).unwrap();
        let lb = piped.prefill(sb, &toks).unwrap();
        assert_eq!(la, lb, "K=1 pipeline must be byte-identical to plain");
        assert_eq!(piped.link_stats().hops, 0, "K=1 never hops");
    }

    #[test]
    fn k2_matches_k1_bit_for_bit() {
        let cfg = ModelConfig::TINY; // 2 layers → 1 per stage
        let toks = ByteTokenizer::new().encode("pipeline k=2");
        let mut one = PipelineEngine::new(1).synthetic(&cfg, 21);
        let mut two = PipelineEngine::new(2).synthetic(&cfg, 21);
        let sa = one.new_sequence();
        let sb = two.new_sequence();
        assert_eq!(one.prefill(sa, &toks).unwrap(), two.prefill(sb, &toks).unwrap());
        // decode a few greedy steps; logits stay identical
        for t in [3u32, 99, 200] {
            let la = one.forward(&[sa], &[t]).unwrap();
            let lb = two.forward(&[sb], &[t]).unwrap();
            assert_eq!(la.data, lb.data);
        }
        // link accounting: one hop per forward call on the 2-stage engine
        let calls = (toks.len() as u64).div_ceil(one.max_batch() as u64) + 3;
        assert_eq!(two.link_stats().hops, calls);
        assert!(two.link_stats().modeled_time_s > 0.0);
        assert_eq!(one.link_stats().hops, 0);
    }

    #[test]
    fn custom_link_and_buckets_are_applied() {
        let cfg = ModelConfig::TINY;
        let e = PipelineEngine::new(2).link(Link::usb3()).buckets(vec![1, 2]).synthetic(&cfg, 3);
        assert_eq!(e.link().kind, crate::interface::link::LinkKind::Usb3);
        assert_eq!(e.max_batch(), 2);
        assert_eq!(e.bucket_sizes(), vec![1, 2]);
    }

    #[test]
    fn pipelined_snapshot_concatenates_to_full_geometry() {
        let cfg = ModelConfig::TINY;
        let toks = ByteTokenizer::new().encode("snap");
        let mut e = PipelineEngine::new(2).synthetic(&cfg, 5);
        let s = e.new_sequence();
        e.prefill(s, &toks).unwrap();
        let snap = e.snapshot_seq(s, 0).unwrap();
        assert_eq!(snap.n_layers, cfg.n_layers);
        assert_eq!(snap.d_model, cfg.d_model);
        assert_eq!(snap.len, toks.len());
    }
}
