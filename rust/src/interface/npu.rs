//! Edge-NPU comparison (paper Table VIII): static published specs for
//! commercial NPUs plus the computed ITA row.

use crate::config::ModelConfig;
use crate::energy::{device_power_w, EnergyParams};

/// One Table VIII row.
#[derive(Debug, Clone)]
pub struct NpuRow {
    pub device: &'static str,
    pub tops: Option<f64>,
    pub power_w: f64,
    pub throughput_tok_s: Option<(f64, f64)>,
    pub cost_usd: Option<f64>,
}

/// Published comparison rows (paper Table VIII).
pub fn commercial_npus() -> Vec<NpuRow> {
    vec![
        NpuRow {
            device: "Apple Neural Engine",
            tops: Some(15.8),
            power_w: 2.0,
            throughput_tok_s: None,
            cost_usd: None,
        },
        NpuRow {
            device: "Qualcomm Hexagon",
            tops: Some(12.0),
            power_w: 1.5,
            throughput_tok_s: Some((20.0, 20.0)),
            cost_usd: None,
        },
        NpuRow {
            device: "Google Coral TPU",
            tops: Some(4.0),
            power_w: 2.0,
            throughput_tok_s: None,
            cost_usd: Some(60.0),
        },
    ]
}

/// The computed ITA row: power from the energy model at 20 tok/s,
/// throughput from the realistic host-CPU scenario, cost from the paper's
/// stated $165 (our self-consistent cost model disagrees — see
/// `cost::tests::llama7b_chiplet_cost_structure`).
pub fn ita_row(cfg: &ModelConfig, unit_cost_usd: f64) -> NpuRow {
    NpuRow {
        device: "ITA (7B Device)",
        tops: None,
        power_w: device_power_w(cfg, &EnergyParams::default(), 20.0),
        throughput_tok_s: Some((10.0, 20.0)),
        cost_usd: Some(unit_cost_usd),
    }
}

/// Energy per token (J) at a given throughput — the efficiency metric the
/// comparison turns on.
pub fn energy_per_token_j(power_w: f64, tok_s: f64) -> f64 {
    power_w / tok_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ita_row_power_near_paper() {
        let row = ita_row(&ModelConfig::LLAMA2_7B, 165.0);
        assert!((0.9..1.3).contains(&row.power_w), "{}", row.power_w);
    }

    #[test]
    fn ita_beats_hexagon_energy_per_token() {
        // Hexagon ≈1.5 W at ≈20 tok/s vs ITA ≈1.1 W at the same rate
        let ita = ita_row(&ModelConfig::LLAMA2_7B, 165.0);
        let hexagon = 1.5;
        assert!(
            energy_per_token_j(ita.power_w, 20.0) < energy_per_token_j(hexagon, 20.0)
        );
    }

    #[test]
    fn table8_has_four_rows() {
        let mut rows = commercial_npus();
        rows.push(ita_row(&ModelConfig::LLAMA2_7B, 165.0));
        assert_eq!(rows.len(), 4);
    }
}
