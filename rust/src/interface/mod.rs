//! The Split-Brain interface: per-token transfer accounting (paper
//! Eq. 7–11), link models and latency analysis (Table III), and the
//! edge-NPU comparison (Table VIII).

pub mod kv_sram;
pub mod link;
pub mod npu;
pub mod protocol;

pub use link::{Link, LinkKind};
pub use protocol::TokenTraffic;

/// Latency budget for one generated token over one link (Table III).
#[derive(Debug, Clone, Copy)]
pub struct TokenLatency {
    pub transfer_s: f64,
    pub device_compute_s: f64,
    pub host_attention_s: f64,
}

impl TokenLatency {
    pub fn total_s(&self) -> f64 {
        self.transfer_s + self.device_compute_s + self.host_attention_s
    }

    pub fn tokens_per_s(&self) -> f64 {
        1.0 / self.total_s()
    }
}

/// Paper Table III fixed terms: 64 µs device pipeline, 5 ms "ideal"
/// (NPU-offloaded) host attention.
pub const DEVICE_COMPUTE_S: f64 = 64e-6;
pub const HOST_ATTENTION_IDEAL_S: f64 = 5e-3;
/// Paper's realistic laptop-CPU attention range (Section VI-C2).
pub const HOST_ATTENTION_CPU_S: (f64, f64) = (50e-3, 100e-3);

/// Table III row: token latency for `traffic` over `link` with a given
/// host-attention time.
pub fn token_latency(traffic: &TokenTraffic, link: &Link, host_attention_s: f64) -> TokenLatency {
    TokenLatency {
        transfer_s: link.transfer_time_s(traffic.total_bytes()),
        device_compute_s: DEVICE_COMPUTE_S,
        host_attention_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn table3_pcie_row() {
        // paper: PCIe 3.0 x4 — 0.21 ms transfer, 5.3 ms total, 188 tok/s
        let traffic = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        let lat = token_latency(&traffic, &Link::pcie3_x4(), HOST_ATTENTION_IDEAL_S);
        assert!((lat.transfer_s * 1e3 - 0.21).abs() < 0.02, "{}", lat.transfer_s * 1e3);
        assert!((lat.total_s() * 1e3 - 5.3).abs() < 0.1);
        assert!((lat.tokens_per_s() - 188.0).abs() < 5.0, "{}", lat.tokens_per_s());
    }

    #[test]
    fn table3_usb3_row() {
        // paper: USB 3.0 — 2.77 ms transfer, 7.9 ms total, 126 tok/s
        let traffic = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        let lat = token_latency(&traffic, &Link::usb3(), HOST_ATTENTION_IDEAL_S);
        assert!((lat.transfer_s * 1e3 - 2.8).abs() < 0.15, "{}", lat.transfer_s * 1e3);
        assert!((lat.tokens_per_s() - 126.0).abs() < 6.0, "{}", lat.tokens_per_s());
    }

    #[test]
    fn realistic_cpu_throughput_10_to_20() {
        let traffic = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        let slow = token_latency(&traffic, &Link::pcie3_x4(), HOST_ATTENTION_CPU_S.1);
        let fast = token_latency(&traffic, &Link::pcie3_x4(), HOST_ATTENTION_CPU_S.0);
        assert!((9.0..11.0).contains(&slow.tokens_per_s()), "{}", slow.tokens_per_s());
        assert!((18.0..21.0).contains(&fast.tokens_per_s()), "{}", fast.tokens_per_s());
    }

    #[test]
    fn transfer_never_dominates_on_fast_links() {
        // the paper's design point: interface latency is negligible vs
        // attention on anything PCIe-class
        let traffic = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        for link in [Link::pcie3_x4(), Link::tb4(), Link::usb4()] {
            let lat = token_latency(&traffic, &link, HOST_ATTENTION_IDEAL_S);
            assert!(lat.transfer_s < 0.1 * lat.host_attention_s, "{:?}", link.kind);
        }
    }
}
