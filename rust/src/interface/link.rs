//! Host↔device link models (paper Table III): PCIe 3.0 x4 (M.2),
//! Thunderbolt 4, USB 3.0, USB 4.0.
//!
//! Each link has a line rate and an *effective* payload rate (protocol
//! overhead included — the paper's own effective numbers), a base
//! round-trip latency, and an incremental BOM cost.

/// Link family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    Pcie3X4,
    Thunderbolt4,
    Usb3,
    Usb4,
}

impl LinkKind {
    pub fn name(&self) -> &'static str {
        match self {
            LinkKind::Pcie3X4 => "PCIe 3.0 x4",
            LinkKind::Thunderbolt4 => "Thunderbolt 4",
            LinkKind::Usb3 => "USB 3.0",
            LinkKind::Usb4 => "USB 4.0",
        }
    }
}

/// A concrete link instance.
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub kind: LinkKind,
    /// Line rate, bits/s (Table III "Bandwidth (Gbps)" column).
    pub line_gbps: f64,
    /// Effective payload bandwidth, bytes/s (the paper's transfer numbers).
    pub effective_bps: f64,
    /// Per-transaction overhead (interrupt + doorbell), seconds.
    pub base_latency_s: f64,
    /// Added BOM cost, $ (Table III "Cost" column).
    pub cost_usd: f64,
}

impl Link {
    pub const fn pcie3_x4() -> Link {
        Link {
            kind: LinkKind::Pcie3X4,
            line_gbps: 32.0,
            effective_bps: 4.0e9,
            base_latency_s: 2e-6,
            cost_usd: 15.0,
        }
    }

    pub const fn tb4() -> Link {
        Link {
            kind: LinkKind::Thunderbolt4,
            line_gbps: 40.0,
            effective_bps: 5.0e9,
            base_latency_s: 4e-6,
            cost_usd: 30.0,
        }
    }

    pub const fn usb3() -> Link {
        Link {
            kind: LinkKind::Usb3,
            line_gbps: 5.0,
            effective_bps: 300.0e6,
            base_latency_s: 30e-6,
            cost_usd: 5.0,
        }
    }

    pub const fn usb4() -> Link {
        Link {
            kind: LinkKind::Usb4,
            line_gbps: 40.0,
            effective_bps: 2.0e9,
            base_latency_s: 10e-6,
            cost_usd: 10.0,
        }
    }

    pub const ALL: [Link; 4] = [Link::pcie3_x4(), Link::tb4(), Link::usb3(), Link::usb4()];

    /// Time to move `bytes` across the link (payload + base overhead).
    pub fn transfer_time_s(&self, bytes: u64) -> f64 {
        self.base_latency_s + bytes as f64 / self.effective_bps
    }

    /// Can this link sustain `bytes_per_s`? (Eq. 11 check: every link can
    /// carry ITA's 16.64 MB/s with orders of magnitude to spare.)
    pub fn sustains(&self, bytes_per_s: f64) -> bool {
        self.effective_bps >= bytes_per_s
    }

    /// Bytes of one pipeline-stage activation handoff: `rows` INT16
    /// hidden-state vectors of width `d_model` (2 bytes per element —
    /// the inter-cartridge wire format of the sharded engine).
    pub const fn activation_hop_bytes(rows: usize, d_model: usize) -> u64 {
        (rows * d_model * 2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_below_line_rate() {
        for l in Link::ALL {
            assert!(l.effective_bps * 8.0 <= l.line_gbps * 1e9, "{:?}", l.kind);
        }
    }

    #[test]
    fn transfer_times_match_table3() {
        // paper transfer column: 0.21 / 0.17 / 2.77 / 0.42 ms for 832 KB
        let bytes = 832 * 1024;
        let ms = |l: &Link| l.transfer_time_s(bytes) * 1e3;
        assert!((ms(&Link::pcie3_x4()) - 0.21).abs() < 0.02);
        assert!((ms(&Link::tb4()) - 0.17).abs() < 0.02);
        assert!((ms(&Link::usb3()) - 2.84).abs() < 0.1); // paper used 832,000 B
        assert!((ms(&Link::usb4()) - 0.43).abs() < 0.02);
    }

    #[test]
    fn all_links_sustain_ita_bandwidth() {
        // Eq. 11: 16.64 MB/s sustained
        for l in Link::ALL {
            assert!(l.sustains(16.64e6), "{:?}", l.kind);
        }
    }

    #[test]
    fn activation_hop_is_int16_rows() {
        assert_eq!(Link::activation_hop_bytes(1, 64), 128);
        assert_eq!(Link::activation_hop_bytes(8, 768), 8 * 768 * 2);
        assert_eq!(Link::activation_hop_bytes(0, 4096), 0);
        // a single decode row at d=768 crosses PCIe in ~2 µs-dominated time
        let t = Link::pcie3_x4().transfer_time_s(Link::activation_hop_bytes(1, 768));
        assert!(t > 2e-6 && t < 3e-6, "{t}");
    }

    #[test]
    fn cost_ordering_matches_paper() {
        assert!(Link::usb3().cost_usd < Link::usb4().cost_usd);
        assert!(Link::usb4().cost_usd < Link::pcie3_x4().cost_usd);
        assert!(Link::pcie3_x4().cost_usd < Link::tb4().cost_usd);
    }
}
