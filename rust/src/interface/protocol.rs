//! Per-token transfer accounting (paper Section VI-C1, Eq. 7–11).
//!
//! The paper's accounting sends K and V to the host (16 KB/layer) and the
//! attention output back (8 KB/layer), plus final logits. **It omits Q** —
//! the host cannot compute `softmax(QKᵀ)` without the query vector, so a
//! faithful implementation must also ship Q (our engine does). Both
//! accountings are provided: `paper_mode` reproduces Eq. 10 exactly;
//! `full_mode` is what the wire actually carries (+8 KB/layer).

use crate::config::ModelConfig;

/// Bytes crossing the host↔device interface for one generated token.
#[derive(Debug, Clone, Copy)]
pub struct TokenTraffic {
    /// Device → host: projection vectors per layer (K,V — and Q in full mode).
    pub d2h_per_layer: u64,
    /// Host → device: attention output per layer (Eq. 8).
    pub h2d_per_layer: u64,
    pub n_layers: u64,
    /// Device → host: final logits (Eq. 9).
    pub logits_bytes: u64,
    /// Bytes per transferred element (paper: INT16 = 2).
    pub bytes_per_elem: u64,
}

impl TokenTraffic {
    /// Paper Eq. 7–9 accounting (K,V only — reproduces 832 KB/token for 7B).
    pub fn paper_mode(cfg: &ModelConfig) -> Self {
        Self::new(cfg, false)
    }

    /// What the protocol actually needs: Q also crosses (engine mode).
    pub fn full_mode(cfg: &ModelConfig) -> Self {
        Self::new(cfg, true)
    }

    fn new(cfg: &ModelConfig, include_q: bool) -> Self {
        let bpe = 2u64;
        let d = cfg.d_model as u64;
        let proj = if include_q { 3 } else { 2 };
        TokenTraffic {
            d2h_per_layer: proj * d * bpe,
            h2d_per_layer: d * bpe,
            n_layers: cfg.n_layers as u64,
            logits_bytes: cfg.vocab as u64 * bpe,
            bytes_per_elem: bpe,
        }
    }

    /// Eq. 10: total bytes per generated token.
    pub fn total_bytes(&self) -> u64 {
        (self.d2h_per_layer + self.h2d_per_layer) * self.n_layers + self.logits_bytes
    }

    /// Eq. 11: sustained bandwidth at a target throughput, bytes/s.
    pub fn bandwidth_at(&self, tokens_per_s: f64) -> f64 {
        self.total_bytes() as f64 * tokens_per_s
    }

    /// Prefill traffic for a prompt of `n` tokens (each prompt token makes
    /// the same per-layer round trips; logits only for the last).
    pub fn prefill_bytes(&self, n: u64) -> u64 {
        (self.d2h_per_layer + self.h2d_per_layer) * self.n_layers * n + self.logits_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn eq7_to_10_reproduce_832_kb() {
        let t = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        assert_eq!(t.d2h_per_layer, 16 * 1024); // Eq. 7: 16 KB/layer
        assert_eq!(t.h2d_per_layer, 8 * 1024); // Eq. 8: 8 KB/layer
        assert_eq!(t.logits_bytes, 64_000); // Eq. 9: ≈64 KB
        // Eq. 10: (16+8)×32 + 64 = 832 KB (paper mixes binary/decimal KB;
        // exact bytes: 24 KiB × 32 + 62.5 KiB)
        let kb = t.total_bytes() as f64 / 1024.0;
        assert!((kb - 830.5).abs() < 1.0, "{kb}");
    }

    #[test]
    fn eq11_bandwidth_at_20_toks() {
        // paper: 16.64 MB/s
        let t = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        let mbs = t.bandwidth_at(20.0) / 1e6;
        assert!((mbs - 17.0).abs() < 0.5, "{mbs}");
    }

    #[test]
    fn full_mode_adds_q() {
        let p = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
        let f = TokenTraffic::full_mode(&ModelConfig::LLAMA2_7B);
        assert_eq!(f.d2h_per_layer - p.d2h_per_layer, 8 * 1024);
        assert!(f.total_bytes() > p.total_bytes());
    }

    #[test]
    fn prefill_scales_linearly() {
        let t = TokenTraffic::full_mode(&ModelConfig::DEMO_100M);
        let one = t.prefill_bytes(1);
        let ten = t.prefill_bytes(10);
        assert!(ten > 9 * one && ten < 10 * one + t.logits_bytes);
    }

    #[test]
    fn demo_config_traffic_small() {
        // demo-100m: d=768, 14 layers → well under 1 MB/token
        let t = TokenTraffic::full_mode(&ModelConfig::DEMO_100M);
        assert!(t.total_bytes() < 200_000, "{}", t.total_bytes());
    }
}
