//! On-device KV-cache option (paper Section VII-E): adding embedded memory
//! to the cartridge so short contexts never leave the die, cutting the
//! host-attention round trip.

use crate::config::ModelConfig;

/// Embedded-DRAM density the paper assumes (0.02 µm²/bit at 28nm).
pub const EDRAM_UM2_PER_BIT: f64 = 0.02;

/// On-device KV configuration.
#[derive(Debug, Clone, Copy)]
pub struct KvSramOption {
    pub capacity_mb: f64,
    /// Bytes per cached element (paper: INT16).
    pub bytes_per_elem: usize,
}

impl KvSramOption {
    /// The paper's proposal: 256 MB for 2K-token contexts.
    pub fn paper_256mb() -> Self {
        KvSramOption { capacity_mb: 256.0, bytes_per_elem: 2 }
    }

    /// Die area for the macro, mm².
    pub fn area_mm2(&self) -> f64 {
        self.capacity_mb * 8.0 * 1024.0 * 1024.0 * EDRAM_UM2_PER_BIT / 1e6
    }

    /// Added unit cost at the paper's $/mm² (≈$0.19/mm² from $52/520mm²…
    /// the paper just says +$8; we derive from silicon cost).
    pub fn added_cost_usd(&self, usd_per_mm2: f64) -> f64 {
        self.area_mm2() * usd_per_mm2
    }

    /// Max context length storable for a model: 2 (K,V) × L × d per token.
    pub fn max_context(&self, cfg: &ModelConfig) -> usize {
        let bytes_per_token =
            2 * cfg.n_layers * cfg.d_model * self.bytes_per_elem;
        (self.capacity_mb * 1024.0 * 1024.0 / bytes_per_token as f64) as usize
    }

    /// Per-token latency with attention on-device for contexts that fit:
    /// the host round trip collapses to activation streaming (paper: 50 ms
    /// → 10 ms claim for CPU hosts).
    pub fn latency_s(&self, cfg: &ModelConfig, context: usize, host_attention_s: f64) -> f64 {
        if context <= self.max_context(cfg) {
            // on-device attention: one pipeline pass, modeled at 1/5 the
            // host cost (the paper's 50→10 ms factor)
            host_attention_s / 5.0
        } else {
            host_attention_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_area_band() {
        // paper says 51.2 mm²; 256 MiB × 8 × 0.02 µm² = 42.9 mm² — the
        // paper appears to use 256e6×... we flag the delta and accept band
        let a = KvSramOption::paper_256mb().area_mm2();
        assert!((40.0..55.0).contains(&a), "{a}");
    }

    #[test]
    fn context_capacity_paper_arithmetic_bug() {
        // PAPER INCONSISTENCY (Section VII-E): "256 MB ... would enable
        // 2K-token contexts". For Llama-2-7B at INT16 a token's K+V is
        // 2 × 32 × 4096 × 2 B = 512 KiB, so 256 MB holds exactly **512**
        // tokens; 2K tokens need 1 GB (or INT8 KV + a smaller model).
        let opt = KvSramOption::paper_256mb();
        let ctx = opt.max_context(&crate::config::ModelConfig::LLAMA2_7B);
        assert_eq!(ctx, 512);
        // INT8 KV on TinyLlama does clear 2K:
        let int8 = KvSramOption { capacity_mb: 256.0, bytes_per_elem: 1 };
        assert!(int8.max_context(&crate::config::ModelConfig::TINYLLAMA_1_1B) >= 2048);
    }

    #[test]
    fn latency_improves_only_within_capacity() {
        let opt = KvSramOption::paper_256mb();
        let cfg = &crate::config::ModelConfig::LLAMA2_7B;
        let fast = opt.latency_s(cfg, 256, 50e-3);
        let slow = opt.latency_s(cfg, 100_000, 50e-3);
        assert!((fast - 10e-3).abs() < 1e-9); // the paper's 50 → 10 ms
        assert!((slow - 50e-3).abs() < 1e-9);
    }

    #[test]
    fn added_cost_single_digit_dollars() {
        // paper: +$8/unit
        let c = KvSramOption::paper_256mb().added_cost_usd(52.0 / 520.0);
        assert!((2.0..12.0).contains(&c), "{c}");
    }
}
