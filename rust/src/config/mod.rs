//! Model topologies and technology parameters.
//!
//! Mirrors `python/compile/configs.py` — the buildable configs must agree
//! exactly with the artifact manifests; the analytic configs are the paper's
//! evaluation targets (Tables II–V, Eq. 7–11).

pub mod tech;

pub use tech::TechParams;

/// A transformer topology (the paper's Section V-C configuration shape).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub n_heads: usize,
    pub vocab: usize,
    /// INT weight width burned into the die (paper: 4).
    pub w_bits: u32,
    /// INT activation width on the device interface (paper: 8).
    pub a_bits: u32,
}

impl ModelConfig {
    pub const fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count; must match `configs.py::ModelConfig.params`.
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        let v = self.vocab as u64;
        let per_layer = 3 * d * d + d * d + 3 * d * f + 2 * d;
        self.n_layers as u64 * per_layer + d + v * d
    }

    /// MAC operations per generated token on the ITA device (all linear
    /// projections; attention itself runs on the host).
    pub fn device_macs_per_token(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ffn as u64;
        let v = self.vocab as u64;
        self.n_layers as u64 * (3 * d * d + d * d + 3 * d * f) + d * v
    }

    pub const TINY: ModelConfig = ModelConfig {
        name: "tiny",
        d_model: 64,
        n_layers: 2,
        d_ffn: 192,
        n_heads: 4,
        vocab: 258,
        w_bits: 4,
        a_bits: 8,
    };

    pub const DEMO_100M: ModelConfig = ModelConfig {
        name: "demo-100m",
        d_model: 768,
        n_layers: 14,
        d_ffn: 2048,
        n_heads: 12,
        vocab: 258,
        w_bits: 4,
        a_bits: 8,
    };

    /// TinyLlama-1.1B (paper Table IV row 1).
    pub const TINYLLAMA_1_1B: ModelConfig = ModelConfig {
        name: "tinyllama-1.1b",
        d_model: 2048,
        n_layers: 22,
        d_ffn: 5632,
        n_heads: 32,
        vocab: 32000,
        w_bits: 4,
        a_bits: 8,
    };

    /// Llama-2-7B (the paper's primary analysis topology, Section V-C).
    pub const LLAMA2_7B: ModelConfig = ModelConfig {
        name: "llama2-7b",
        d_model: 4096,
        n_layers: 32,
        d_ffn: 11008,
        n_heads: 32,
        vocab: 32000,
        w_bits: 4,
        a_bits: 8,
    };

    /// Llama-2-13B (paper Table IV row 4).
    pub const LLAMA2_13B: ModelConfig = ModelConfig {
        name: "llama2-13b",
        d_model: 5120,
        n_layers: 40,
        d_ffn: 13824,
        n_heads: 40,
        vocab: 32000,
        w_bits: 4,
        a_bits: 8,
    };

    pub fn by_name(name: &str) -> Option<&'static ModelConfig> {
        ALL_CONFIGS.iter().find(|c| c.name == name)
    }
}

pub const ALL_CONFIGS: &[ModelConfig] = &[
    ModelConfig::TINY,
    ModelConfig::DEMO_100M,
    ModelConfig::TINYLLAMA_1_1B,
    ModelConfig::LLAMA2_7B,
    ModelConfig::LLAMA2_13B,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_paper_scale() {
        // The paper rounds: 1.1B, 7B, 13B. Effective Llama-2-7B linear-layer
        // count (our accounting, incl. tied embedding) lands at ~6.6B.
        let t = ModelConfig::TINYLLAMA_1_1B.params() as f64 / 1e9;
        assert!((0.95..1.25).contains(&t), "{t}");
        let s = ModelConfig::LLAMA2_7B.params() as f64 / 1e9;
        assert!((6.2..7.2).contains(&s), "{s}");
        let m = ModelConfig::LLAMA2_13B.params() as f64 / 1e9;
        assert!((12.0..14.0).contains(&m), "{m}");
    }

    #[test]
    fn demo_config_is_about_100m() {
        let p = ModelConfig::DEMO_100M.params() as f64;
        assert!((96e6..103e6).contains(&p), "{p}");
    }

    #[test]
    fn head_dims_divide() {
        for c in ALL_CONFIGS {
            assert_eq!(c.d_model % c.n_heads, 0, "{}", c.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelConfig::by_name("llama2-7b").unwrap().d_model, 4096);
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn device_macs_dominated_by_ffn() {
        // Paper Section II-B: FFN layers account for >85% of compute FLOPs
        // (their claim folds Wo + FFN; we check FFN alone is >60%).
        let c = &ModelConfig::LLAMA2_7B;
        let d = c.d_model as u64;
        let f = c.d_ffn as u64;
        let ffn = c.n_layers as u64 * 3 * d * f;
        let frac = ffn as f64 / c.device_macs_per_token() as f64;
        assert!(frac > 0.6, "{frac}");
    }
}
