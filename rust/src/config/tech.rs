//! Technology parameters for the analytical models (paper Section V).
//!
//! All defaults are the paper's published constants so the analytic tables
//! reproduce near-exactly; every field is adjustable for the design-space
//! sweeps in `examples/design_space.rs`.

/// 28nm-class process + energy constants (paper Sections V-A, V-C, VI-B).
#[derive(Debug, Clone, PartialEq)]
pub struct TechParams {
    /// Process node label (documentation only).
    pub node: &'static str,
    /// Clock frequency, Hz (paper: 500 MHz conservative 28nm closure).
    pub clock_hz: f64,
    /// Supply voltage, V (paper: 0.9).
    pub vdd: f64,
    /// Switching activity for dataflow patterns (paper: 0.15).
    pub alpha: f64,
    /// Interconnect capacitance, F/µm (paper: 0.2 fF/µm Metal-3).
    pub wire_cap_f_per_um: f64,
    /// Average on-die traversal distance per layer, µm (paper: 5 mm).
    pub avg_wire_um: f64,
    /// Static leakage per gate, W (paper: 10 nW for 28nm LP).
    pub leakage_w_per_gate: f64,
    /// ROM-like weight storage density, µm²/bit (paper: 0.12).
    pub storage_um2_per_bit: f64,
    /// SRAM density for comparisons, µm²/bit (paper: 0.3).
    pub sram_um2_per_bit: f64,
    /// Global-interconnect routing multiplier (paper optimistic: 1.4).
    pub routing_overhead: f64,
    /// Conservative routing multiplier (paper: 3.0).
    pub routing_overhead_conservative: f64,
    /// Control/SerDes/power-management area adder (paper: +15%).
    pub control_overhead: f64,
    /// Post-synthesis optimization factor implied by the paper's final die
    /// areas (850→520 mm², 5410→3680 mm²; see DESIGN.md §8 — the paper is
    /// internally inconsistent between 0.61 and 0.68, we use 0.68).
    pub synthesis_opt: f64,
    /// 300 mm wafer cost, $ (paper: $3,000–5,000; Table IV uses $4,500).
    pub wafer_cost_usd: f64,
    /// Wafer diameter, mm.
    pub wafer_diameter_mm: f64,
    /// Die yield (paper optimistic: 0.75; conservative 0.55–0.60).
    pub yield_: f64,
    /// Mask-set / NRE cost, $ (paper: $2–3M; Table V uses $2.5M).
    pub nre_usd: f64,
}

impl TechParams {
    /// The paper's 28nm configuration.
    pub const fn paper_28nm() -> Self {
        TechParams {
            node: "28nm planar CMOS",
            clock_hz: 500e6,
            vdd: 0.9,
            alpha: 0.15,
            wire_cap_f_per_um: 0.2e-15,
            avg_wire_um: 5_000.0,
            leakage_w_per_gate: 10e-9,
            storage_um2_per_bit: 0.12,
            sram_um2_per_bit: 0.3,
            routing_overhead: 1.4,
            routing_overhead_conservative: 3.0,
            control_overhead: 0.15,
            synthesis_opt: 0.68,
            wafer_cost_usd: 4_500.0,
            wafer_diameter_mm: 300.0,
            yield_: 0.75,
            nre_usd: 2_500_000.0,
        }
    }

    /// Dynamic switching energy of one average gate, J
    /// (E = alpha * C * Vdd^2 with a nominal 1 fF gate load).
    pub fn gate_switch_energy_j(&self) -> f64 {
        self.alpha * 1e-15 * self.vdd * self.vdd
    }

    /// Energy to drive the average per-layer wire span, J/bit.
    pub fn wire_energy_j_per_bit(&self) -> f64 {
        self.alpha * self.wire_cap_f_per_um * self.avg_wire_um * self.vdd * self.vdd
    }
}

impl Default for TechParams {
    fn default() -> Self {
        Self::paper_28nm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let t = TechParams::paper_28nm();
        assert_eq!(t.clock_hz, 500e6);
        assert_eq!(t.vdd, 0.9);
        assert_eq!(t.storage_um2_per_bit, 0.12);
    }

    #[test]
    fn wire_energy_order_of_magnitude() {
        // 0.15 * 0.2fF/µm * 5mm * 0.81V² ≈ 0.12 pJ/bit — the scale that makes
        // ITA's 4 pJ "on-chip wire" row (32-bit datapath) plausible.
        let e = TechParams::paper_28nm().wire_energy_j_per_bit();
        assert!(e > 0.05e-12 && e < 0.5e-12, "{e}");
    }
}
