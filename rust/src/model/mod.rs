//! Runtime model structures: row-major matrices and the per-layer weight
//! pack used by the pure-rust reference device.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::runtime::{Manifest, WeightStore};
use crate::util::prng::Prng;

/// Minimal row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Quantized linear layer: integer-valued f32 weights [K, N] (recomposed
/// INT4) + per-output-channel scale [N].
#[derive(Debug, Clone)]
pub struct QLinear {
    pub k: usize,
    pub n: usize,
    /// Integer-valued weights (each in [-7, 7]); row-major [K, N].
    pub w: Vec<f32>,
    pub scale: Vec<f32>,
}

impl QLinear {
    pub fn load(store: &WeightStore, w_name: &str, s_name: &str) -> Result<QLinear> {
        let meta = store.meta(w_name)?;
        anyhow::ensure!(meta.shape.len() == 2, "{w_name} not 2-D");
        let (k, n) = (meta.shape[0], meta.shape[1]);
        Ok(QLinear { k, n, w: store.f32(w_name)?, scale: store.f32(s_name)? })
    }
}

/// One transformer layer's device-side weights (fused-variant blobs).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub g1: Vec<f32>,
    pub wqkv: QLinear,
    pub g2: Vec<f32>,
    pub wo: QLinear,
    pub w1: QLinear,
    pub w3: QLinear,
    pub w2: QLinear,
}

/// Full model weights for the rust reference device + host embedding.
pub struct ModelWeights {
    pub layers: Vec<LayerWeights>,
    pub gf: Vec<f32>,
    pub we: QLinear,
    /// Host-side embedding lookup table [vocab, d_model] (dequantized).
    pub emb: Mat,
}

impl ModelWeights {
    /// Load the fused-variant weight pack for every layer.
    pub fn load(manifest: &Manifest, store: &WeightStore) -> Result<ModelWeights> {
        let mut layers = Vec::with_capacity(manifest.n_layers);
        for l in 0..manifest.n_layers {
            layers.push(LayerWeights {
                g1: store.f32(&format!("g1_l{l}"))?,
                wqkv: QLinear::load(store, &format!("wqkv_f32_l{l}"), &format!("wqkv_scale_l{l}"))?,
                g2: store.f32(&format!("g2_l{l}"))?,
                wo: QLinear::load(store, &format!("wo_f32_l{l}"), &format!("wo_scale_l{l}"))?,
                w1: QLinear::load(store, &format!("w1_f32_l{l}"), &format!("w1_scale_l{l}"))?,
                w3: QLinear::load(store, &format!("w3_f32_l{l}"), &format!("w3_scale_l{l}"))?,
                w2: QLinear::load(store, &format!("w2_f32_l{l}"), &format!("w2_scale_l{l}"))?,
            });
        }
        let emb_data = store.f32("emb_f32")?;
        Ok(ModelWeights {
            layers,
            gf: store.f32("gf")?,
            we: QLinear::load(store, "we_f32", "we_scale")?,
            emb: Mat::new(manifest.vocab, manifest.d_model, emb_data),
        })
    }

    /// Deterministic synthetic weights for the artifact-free test tier: the
    /// same INT4 value range and per-channel scale structure as real
    /// artifacts, generated from a seeded [`Prng`] instead of `make
    /// artifacts`. Two calls with equal `(cfg, seed)` are byte-identical on
    /// every platform, so differential and fleet tests can run from a clean
    /// checkout.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Prng::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ffn;
        let v = cfg.vocab;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerWeights {
                g1: synth_gain(&mut rng, d),
                wqkv: synth_qlinear(&mut rng, d, 3 * d),
                g2: synth_gain(&mut rng, d),
                wo: synth_qlinear(&mut rng, d, d),
                w1: synth_qlinear(&mut rng, d, f),
                w3: synth_qlinear(&mut rng, d, f),
                w2: synth_qlinear(&mut rng, f, d),
            });
        }
        let gf = synth_gain(&mut rng, d);
        let we = synth_qlinear(&mut rng, d, v);
        let emb: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.5).collect();
        ModelWeights { layers, gf, we, emb: Mat::new(v, d, emb) }
    }
}

/// Integer-valued INT4 weights in [-7, 7] plus a per-channel scale that
/// keeps activations O(1) through the quantized matmul (mirrors the
/// magnitude structure `python/compile/quantize.py` produces).
fn synth_qlinear(rng: &mut Prng, k: usize, n: usize) -> QLinear {
    let w: Vec<f32> = (0..k * n).map(|_| rng.range_i64(-7, 7) as f32).collect();
    let base = 1.0 / (7.0 * (k as f32).sqrt());
    let scale: Vec<f32> =
        (0..n).map(|_| base * (0.5 + rng.uniform() as f32)).collect();
    QLinear { k, n, w, scale }
}

/// RMSNorm gains near 1.
fn synth_gain(rng: &mut Prng, d: usize) -> Vec<f32> {
    (0..d).map(|_| 1.0 + rng.normal() as f32 * 0.05).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_row_access() {
        let m = Mat::new(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn mat_shape_checked() {
        Mat::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn synthetic_weights_deterministic_and_int4() {
        let cfg = crate::config::ModelConfig::TINY;
        let a = ModelWeights::synthetic(&cfg, 7);
        let b = ModelWeights::synthetic(&cfg, 7);
        assert_eq!(a.layers.len(), cfg.n_layers);
        assert_eq!(a.emb.rows, cfg.vocab);
        assert_eq!(a.emb.cols, cfg.d_model);
        assert_eq!(a.emb.data, b.emb.data, "same seed must be byte-identical");
        assert_eq!(a.layers[0].wqkv.w, b.layers[0].wqkv.w);
        for &v in &a.layers[0].wqkv.w {
            assert_eq!(v, v.round());
            assert!((-7.0..=7.0).contains(&v));
        }
        let c = ModelWeights::synthetic(&cfg, 8);
        assert_ne!(a.layers[0].wqkv.w, c.layers[0].wqkv.w, "seeds must differ");
    }

    #[test]
    fn load_tiny_weights_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("MANIFEST.txt").exists() {
            return;
        }
        let (m, s) = crate::runtime::weights::load_artifacts(&dir).unwrap();
        let w = ModelWeights::load(&m, &s).unwrap();
        assert_eq!(w.layers.len(), 2);
        assert_eq!(w.layers[0].wqkv.k, 64);
        assert_eq!(w.layers[0].wqkv.n, 192);
        assert_eq!(w.emb.rows, 258);
        // weights are integer-valued INT4
        for &v in w.layers[0].wqkv.w.iter().take(100) {
            assert_eq!(v, v.round());
            assert!((-8.0..=7.0).contains(&v));
        }
    }
}
