//! Paper-table renderers: each function regenerates one table/figure of the
//! paper from the analytical models and returns printable rows with the
//! paper's published value alongside ours. Shared by the CLI (`ita
//! tables`), `examples/paper_tables.rs`, and the `benches/table*.rs`
//! harnesses.

use crate::area::{estimate, Routing};
use crate::config::{ModelConfig, TechParams};
use crate::cost::{cost_at_volume, unit_cost, TABLE5_VOLUMES};
use crate::energy::{system_power, EnergyParams};
use crate::interface::npu::{commercial_npus, ita_row};
use crate::interface::{
    token_latency, Link, TokenTraffic, HOST_ATTENTION_CPU_S, HOST_ATTENTION_IDEAL_S,
};
use crate::security::{attack_vectors, extraction_floor_usd, Target};
use crate::synth::fpga::{proto_network_weights, table6, table7, FpgaCosts, XC7Z020};
use crate::synth::gates::CellCosts;
use crate::synth::mac::{sample_int4_weights, table1};
use crate::util::fmt;

/// A rendered table.
pub struct Report {
    pub title: String,
    pub header: Vec<&'static str>,
    pub rows: Vec<Vec<String>>,
    /// Free-text notes (deviations from the paper, caveats).
    pub notes: Vec<String>,
}

impl Report {
    pub fn print(&self) {
        crate::util::benchkit::print_table(
            &self.title,
            &self.header,
            &self.rows,
        );
        for n in &self.notes {
            println!("  note: {n}");
        }
    }
}

fn f1(v: f64) -> String {
    format!("{v:.1}")
}


/// Table I: gate count per MAC unit.
pub fn table1_report() -> Report {
    let weights = sample_int4_weights(65_536, 0x17A);
    let lit = table1(&CellCosts::asic_28nm(), &weights);
    let cal = table1(&CellCosts::paper_calibrated(), &weights);
    let rows = vec![
        vec!["Generic INT8 MAC".into(), "1,180".into(),
             fmt::thousands(lit.generic as u64), fmt::thousands(cal.generic as u64)],
        vec!["ITA constant-coeff (expected)".into(), "243".into(),
             fmt::thousands(lit.ita_expected as u64), fmt::thousands(cal.ita_expected as u64)],
        vec!["ITA constant-coeff (worst)".into(), "-".into(),
             fmt::thousands(lit.ita_worst as u64), fmt::thousands(cal.ita_worst as u64)],
        vec!["  shift-add tree".into(), "156".into(),
             f1(lit.ita_breakdown.multiply), f1(cal.ita_breakdown.multiply)],
        vec!["  accumulator".into(), "68".into(),
             f1(lit.ita_breakdown.accumulator), f1(cal.ita_breakdown.accumulator)],
        vec!["  pipeline register".into(), "19".into(),
             f1(lit.ita_breakdown.pipeline), f1(cal.ita_breakdown.pipeline)],
        vec!["Reduction".into(), "4.85x".into(),
             format!("{:.2}x", lit.reduction), format!("{:.2}x", cal.reduction)],
    ];
    Report {
        title: "Table I — gate count per MAC unit (NAND2-equivalents)".into(),
        header: vec!["Row", "Paper", "Ours (lit. cells)", "Ours (calibrated)"],
        rows,
        notes: vec![
            format!(
                "expected-case over {:.1}% pruned synthetic INT4 weights; calibrated = \
                 same netlists, global scale pinning generic MAC to the paper's 1,180",
                lit.pruned_fraction * 100.0
            ),
            "our expected-case reduction exceeds the paper's 4.85x because their ITA row \
             prices a full-width accumulator; our spatial-regime accumulator is the \
             tree-adder share (DESIGN.md §8)".into(),
        ],
    }
}

/// Table II: energy per MAC operation.
pub fn table2_report() -> Report {
    let e = EnergyParams::default();
    let stacks = [e.gpu_fp16(), e.gpu_int8(), e.ita()];
    let paper = [
        ("GPU (FP16)", 320.0, 80.0, 1.1, 401.1),
        ("GPU (INT8)", 160.0, 40.0, 1.0, 201.0),
        ("ITA", 0.0, 4.0, 0.05, 4.05),
    ];
    let mut rows = Vec::new();
    for (s, p) in stacks.iter().zip(paper) {
        rows.push(vec![
            s.name.into(),
            format!("{} / {}", fmt::picojoules(s.dram_fetch_pj), fmt::picojoules(p.1)),
            format!("{} / {}", fmt::picojoules(s.wire_pj), fmt::picojoules(p.2)),
            format!("{} / {}", fmt::picojoules(s.compute_pj), fmt::picojoules(p.3)),
            format!("{} / {}", fmt::picojoules(s.total_pj()), fmt::picojoules(p.4)),
        ]);
    }
    rows.push(vec![
        "ITA vs INT8".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}x / 49.6x", e.improvement_vs_int8()),
    ]);
    let sp = system_power(&ModelConfig::LLAMA2_7B, &e, 20.0);
    Report {
        title: "Table II — energy per MAC (ours / paper)".into(),
        header: vec!["Arch", "DRAM fetch", "On-chip wire", "Compute", "Total"],
        rows,
        notes: vec![format!(
            "system power @20 tok/s: device {:.2} W (paper 1.13), SerDes {:.1} W, host \
             {:.0}-{:.0} W → total {:.1}-{:.1} W (paper 7-12 W)",
            sp.device_w, sp.serdes_w, sp.host_cpu_w.0, sp.host_cpu_w.1, sp.total_w.0, sp.total_w.1
        )],
    }
}

/// Fig 2: stacked energy breakdown (same data as Table II, series form).
pub fn fig2_report() -> Report {
    let e = EnergyParams::default();
    let mut rows = Vec::new();
    for s in [e.gpu_fp16(), e.gpu_int8(), e.ita()] {
        let total = s.total_pj();
        let bar = |v: f64| "#".repeat((v / total * 40.0).round() as usize);
        rows.push(vec![
            s.name.into(),
            format!("{:<40}", bar(s.dram_fetch_pj)),
            format!("{:<40}", bar(s.wire_pj)),
            format!("{}", fmt::picojoules(total)),
        ]);
    }
    Report {
        title: "Fig 2 — energy breakdown per parameter op (DRAM share | wire share)".into(),
        header: vec!["Arch", "DRAM", "Wire", "Total"],
        rows,
        notes: vec!["ITA eliminates the dominant DRAM bar entirely".into()],
    }
}

/// Eq. 7–11 + Table III: transfers and interface latency.
pub fn table3_report(measured_host_attention_s: Option<f64>) -> Report {
    let cfg = &ModelConfig::LLAMA2_7B;
    let traffic = TokenTraffic::paper_mode(cfg);
    let full = TokenTraffic::full_mode(cfg);
    let paper = [(0.21, 5.3, 188.0), (0.17, 5.2, 192.0), (2.77, 7.9, 126.0), (0.42, 5.5, 182.0)];
    let mut rows = Vec::new();
    for (link, p) in Link::ALL.iter().zip(paper) {
        let lat = token_latency(&traffic, link, HOST_ATTENTION_IDEAL_S);
        rows.push(vec![
            link.kind.name().into(),
            format!("{:.0}", link.line_gbps),
            format!("{:.2} / {:.2} ms", lat.transfer_s * 1e3, p.0),
            format!("{:.1} / {:.1} ms", lat.total_s() * 1e3, p.1),
            format!("{:.0} / {:.0}", lat.tokens_per_s(), p.2),
            format!("+${:.0}", link.cost_usd),
        ]);
    }
    let mut notes = vec![
        format!(
            "Eq.10: {:.0} KB/token (paper 832); Eq.11 @20 tok/s: {:.2} MB/s (paper 16.64)",
            traffic.total_bytes() as f64 / 1024.0,
            traffic.bandwidth_at(20.0) / 1e6
        ),
        format!(
            "paper accounting omits Q (host cannot form QK^T without it); faithful \
             protocol carries {:.0} KB/token (+{:.0}%)",
            full.total_bytes() as f64 / 1024.0,
            (full.total_bytes() as f64 / traffic.total_bytes() as f64 - 1.0) * 100.0
        ),
        {
            let slow = token_latency(&traffic, &Link::pcie3_x4(), HOST_ATTENTION_CPU_S.1);
            let fast = token_latency(&traffic, &Link::pcie3_x4(), HOST_ATTENTION_CPU_S.0);
            format!(
                "realistic CPU attention (50-100 ms): {:.0}-{:.0} tok/s (paper 10-20)",
                slow.tokens_per_s(),
                fast.tokens_per_s()
            )
        },
    ];
    if let Some(att) = measured_host_attention_s {
        let lat = token_latency(&traffic, &Link::pcie3_x4(), att);
        notes.push(format!(
            "with OUR measured host attention ({:.2} ms for 32 layers): {:.0} tok/s",
            att * 1e3,
            lat.tokens_per_s()
        ));
    }
    Report {
        title: "Table III — interface comparison (ours / paper)".into(),
        header: vec!["Interface", "Gbps", "Transfer", "Total", "tok/s", "Cost"],
        rows,
        notes,
    }
}

/// Table IV: die area / configuration / cost.
pub fn table4_report() -> Report {
    let tech = TechParams::paper_28nm();
    let entries: [(&ModelConfig, Routing, f64, &str); 4] = [
        (&ModelConfig::TINYLLAMA_1_1B, Routing::Optimistic, 520.0, "$52"),
        (&ModelConfig::LLAMA2_7B, Routing::Optimistic, 3680.0, "$165"),
        (&ModelConfig::LLAMA2_7B, Routing::Conservative, 7885.0, "~$350"),
        (&ModelConfig::LLAMA2_13B, Routing::Optimistic, 6760.0, "$298"),
    ];
    let mut rows = Vec::new();
    for (cfg, routing, paper_area, paper_cost) in entries {
        let est = estimate(cfg, &tech, routing);
        let u = unit_cost(&est, &tech);
        let config = if est.monolithic {
            "mono".to_string()
        } else {
            format!("{}-chiplet", est.n_chiplets)
        };
        rows.push(vec![
            format!(
                "{}{}",
                cfg.name,
                if routing == Routing::Conservative { " (cons.)" } else { "" }
            ),
            format!("{:.1}B", cfg.params() as f64 / 1e9),
            format!("{:.0} / {:.0} mm²", est.final_mm2, paper_area),
            config,
            format!("{} / {}", fmt::dollars(u.total()), paper_cost),
        ]);
    }
    Report {
        title: "Table IV — scalability analysis (ours / paper)".into(),
        header: vec!["Model", "Params", "Die area", "Config", "Unit cost"],
        rows,
        notes: vec![
            "our params use the true topology (1.2B for 'TinyLlama-1.1B'), the paper \
             rounds down — areas land 5-10% above theirs".into(),
            "paper's 7B cost assumes $14/chiplet, inconsistent with its own $52 for a \
             520 mm² die; our wafer model prices 460 mm² chiplets honestly (~$40), \
             hence the higher 7B unit cost".into(),
        ],
    }
}

/// Table V: cost vs production volume.
pub fn table5_report() -> Report {
    let tech = TechParams::paper_28nm();
    let paper = [(314.0, 415.0), (89.0, 190.0), (66.0, 167.0)];
    let small = unit_cost(&estimate(&ModelConfig::TINYLLAMA_1_1B, &tech, Routing::Optimistic), &tech);
    let big = unit_cost(&estimate(&ModelConfig::LLAMA2_7B, &tech, Routing::Optimistic), &tech);
    let mut rows = Vec::new();
    for (&vol, p) in TABLE5_VOLUMES.iter().zip(paper.iter()) {
        let s = cost_at_volume(&small, &tech, vol);
        let b = cost_at_volume(&big, &tech, vol);
        rows.push(vec![
            fmt::thousands(vol),
            fmt::dollars(s.nre_per_unit),
            format!("{} / ${:.0}", fmt::dollars(s.unit_total), p.0),
            format!("{} / ${:.0}", fmt::dollars(b.unit_total), p.1),
        ]);
    }
    Report {
        title: "Table V — manufacturing cost vs volume (ours / paper)".into(),
        header: vec!["Volume", "NRE/unit", "1.1B cost", "7B cost"],
        rows,
        notes: vec!["NRE amortization matches exactly; unit deltas inherit Table IV's".into()],
    }
}

/// Table VI: full-network FPGA utilization.
pub fn table6_report() -> Report {
    let t = table6(&proto_network_weights(0x17A), &FpgaCosts::default());
    let pct = |v: f64, cap: u32| format!("{:.0}%", v / cap as f64 * 100.0);
    let rows = vec![
        vec!["LUTs".into(),
             format!("{} ({})", fmt::thousands(t.baseline.luts as u64), pct(t.baseline.luts, XC7Z020.luts)),
             "11,309 (21%)".into(),
             format!("{} ({})", fmt::thousands(t.hardwired.luts as u64), pct(t.hardwired.luts, XC7Z020.luts)),
             "170,502 (321%)".into()],
        vec!["CARRY4".into(),
             fmt::thousands(t.baseline.carry4 as u64), "1,540".into(),
             fmt::thousands(t.hardwired.carry4 as u64), "44,442".into()],
        vec!["Registers".into(),
             fmt::thousands(t.baseline.registers as u64), "5,625".into(),
             fmt::thousands(t.hardwired.registers as u64), "7,540".into()],
        vec!["Fits xc7z020?".into(),
             format!("{}", t.baseline_fits), "yes".into(),
             format!("{}", t.hardwired_fits), "no".into()],
    ];
    Report {
        title: format!(
            "Table VI — 64→128→64 network on Zynq-7020 ({} MACs)",
            fmt::thousands(t.n_macs as u64)
        ),
        header: vec!["Resource", "Baseline (ours)", "Baseline (paper)", "Hardwired (ours)", "Hardwired (paper)"],
        rows,
        notes: vec![format!(
            "hardwired/baseline LUT ratio: {:.1}x (paper 15.1x); headline claims hold: \
             baseline fits, hardwired exceeds the device by {:.1}x",
            t.lut_ratio,
            t.hardwired.luts / XC7Z020.luts as f64
        )],
    }
}

/// Table VII: single-neuron comparison.
pub fn table7_report() -> Report {
    let weights = sample_int4_weights(64, 42);
    let t = table7(&weights, &FpgaCosts::default());
    let rows = vec![
        vec!["LUTs".into(), format!("{:.0}", t.generic.luts), "1,425".into(),
             format!("{:.0}", t.hardwired.luts), "788".into()],
        vec!["CARRY4".into(), format!("{:.0}", t.generic.carry4), "407".into(),
             format!("{:.0}", t.hardwired.carry4), "201".into()],
        vec!["Registers".into(), format!("{:.0}", t.generic.registers), "644".into(),
             format!("{:.0}", t.hardwired.registers), "31".into()],
        vec!["LUTs/MAC".into(),
             f1(t.generic.luts / t.n_macs as f64), "22.3".into(),
             f1(t.hardwired.luts / t.n_macs as f64), "12.3".into()],
        vec!["LUT reduction".into(), "-".into(), "-".into(),
             format!("{:.2}x", t.lut_reduction), "1.81x".into()],
        vec!["Reg reduction".into(), "-".into(), "-".into(),
             format!("{:.1}x", t.reg_reduction), "20.8x".into()],
    ];
    Report {
        title: "Table VII — single neuron, 64 parallel MACs (ours vs paper)".into(),
        header: vec!["Resource", "Generic (ours)", "Generic (paper)", "Hardwired (ours)", "Hardwired (paper)"],
        rows,
        notes: vec![],
    }
}

/// Table VIII: edge-NPU comparison.
pub fn table8_report() -> Report {
    let tech = TechParams::paper_28nm();
    let cost = unit_cost(&estimate(&ModelConfig::LLAMA2_7B, &tech, Routing::Optimistic), &tech);
    let mut rows = Vec::new();
    for r in commercial_npus() {
        rows.push(vec![
            r.device.into(),
            r.tops.map_or("N/A".into(), |t| f1(t)),
            format!("{:.1} W", r.power_w),
            r.throughput_tok_s.map_or("N/A".into(), |(a, b)| format!("{a:.0}-{b:.0} tok/s")),
            r.cost_usd.map_or("N/A".into(), fmt::dollars),
        ]);
    }
    let ita = ita_row(&ModelConfig::LLAMA2_7B, cost.total());
    rows.push(vec![
        ita.device.into(),
        "N/A".into(),
        format!("{:.1} W (paper 1.1)", ita.power_w),
        "10-20 tok/s".into(),
        format!("{} (paper $165)", fmt::dollars(ita.cost_usd.unwrap())),
    ]);
    Report {
        title: "Table VIII — comparison with commercial edge NPUs".into(),
        header: vec!["Device", "TOPS", "Power", "Throughput", "Cost"],
        rows,
        notes: vec!["ITA power/cost rows computed from our energy/cost models".into()],
    }
}

/// Fig 3: extraction-cost barrier.
pub fn fig3_report() -> Report {
    let mut rows = Vec::new();
    for a in attack_vectors() {
        rows.push(vec![
            a.name.into(),
            format!("{:?}", a.applies_to),
            format!(
                "{}-{}",
                fmt::dollars(a.equipment_usd.0),
                fmt::dollars(a.equipment_usd.1)
            ),
            format!("{:.0}-{:.0} d", a.time_days.0, a.time_days.1),
            fmt::dollars(a.min_cost_usd()),
        ]);
    }
    let sw = extraction_floor_usd(Target::SoftwareReadable);
    let hw = extraction_floor_usd(Target::PhysicalLogic);
    Report {
        title: "Fig 3 — economic barrier to model extraction".into(),
        header: vec!["Attack", "Target", "Equipment", "Time", "Min total"],
        rows,
        notes: vec![format!(
            "extraction floor: software {} → ITA {} ({:.0}x; paper: $1-2K → $50K+, 25x)",
            fmt::dollars(sw.max(2000.0)),
            fmt::dollars(hw),
            hw / sw.max(2000.0)
        )],
    }
}

/// All reports in paper order.
pub fn all_reports() -> Vec<Report> {
    vec![
        table1_report(),
        table2_report(),
        fig2_report(),
        table3_report(None),
        table4_report(),
        table5_report(),
        table6_report(),
        table7_report(),
        table8_report(),
        fig3_report(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        let reports = all_reports();
        assert_eq!(reports.len(), 10);
        for r in &reports {
            assert!(!r.rows.is_empty(), "{}", r.title);
            for row in &r.rows {
                assert_eq!(row.len(), r.header.len(), "{}", r.title);
            }
        }
    }

    #[test]
    fn table3_accepts_measured_attention() {
        let r = table3_report(Some(0.012));
        assert!(r.notes.iter().any(|n| n.contains("OUR measured")));
    }
}
