//! Energy models (paper Sections II-A, V-A, VI-B): per-MAC energy stacks
//! (Table II / Fig 2), the DRAM-fetch floor (Eq. 1–2), and whole-system
//! power (Section VI-B1).

pub mod hybrid;

use crate::config::{ModelConfig, TechParams};

/// One architecture's per-MAC energy stack, in picojoules (Table II rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyStack {
    pub name: &'static str,
    pub dram_fetch_pj: f64,
    pub wire_pj: f64,
    pub compute_pj: f64,
}

impl EnergyStack {
    pub fn total_pj(&self) -> f64 {
        self.dram_fetch_pj + self.wire_pj + self.compute_pj
    }
}

/// Energy model parameters (paper's published constants as defaults).
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// DRAM/HBM access energy per bit (paper [2]: ≈20 pJ/bit LPDDR5/HBM2e).
    pub dram_pj_per_bit: f64,
    /// GPU on-chip wire+SRAM movement per bit (derived from the paper's
    /// 80 pJ FP16 row: 5 pJ/bit across the cache/register hierarchy).
    pub gpu_wire_pj_per_bit: f64,
    /// GPU FP16 MAC energy (paper: 1.1 pJ, 7nm FinFET [23]).
    pub gpu_fp16_mac_pj: f64,
    /// GPU INT8 MAC energy (paper: 1.0 pJ).
    pub gpu_int8_mac_pj: f64,
    /// ITA on-chip wire energy per MAC (paper: 4.0 pJ — one 32-bit operand
    /// hop across the ≈5 mm dataflow pipeline stage).
    pub ita_wire_pj: f64,
    /// ITA hardwired MAC energy (paper: 0.05 pJ — a handful of gate
    /// switches, no operand fetch).
    pub ita_mac_pj: f64,
    /// Paper counts 2 "operations" per parameter per token (multiply+add)
    /// in its device-power arithmetic (Section VI-B1).
    pub ops_per_param: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            dram_pj_per_bit: 20.0,
            gpu_wire_pj_per_bit: 5.0,
            gpu_fp16_mac_pj: 1.1,
            gpu_int8_mac_pj: 1.0,
            ita_wire_pj: 4.0,
            ita_mac_pj: 0.05,
            ops_per_param: 2.0,
        }
    }
}

impl EnergyParams {
    /// Derive the ITA wire energy from first principles instead of the
    /// paper's quoted 4 pJ: a 32-bit operand over the average wire span.
    pub fn ita_wire_pj_derived(tech: &TechParams) -> f64 {
        32.0 * tech.wire_energy_j_per_bit() * 1e12
    }

    /// Table II row: GPU running FP16 (16-bit weight fetch per MAC).
    pub fn gpu_fp16(&self) -> EnergyStack {
        EnergyStack {
            name: "GPU (FP16)",
            dram_fetch_pj: 16.0 * self.dram_pj_per_bit,
            wire_pj: 16.0 * self.gpu_wire_pj_per_bit,
            compute_pj: self.gpu_fp16_mac_pj,
        }
    }

    /// Table II row: GPU in INT8 tensor-core mode (8-bit fetch per MAC).
    pub fn gpu_int8(&self) -> EnergyStack {
        EnergyStack {
            name: "GPU (INT8)",
            dram_fetch_pj: 8.0 * self.dram_pj_per_bit,
            wire_pj: 8.0 * self.gpu_wire_pj_per_bit,
            compute_pj: self.gpu_int8_mac_pj,
        }
    }

    /// Table II row: ITA — zero fetch, short wires, hardwired compute.
    pub fn ita(&self) -> EnergyStack {
        EnergyStack {
            name: "ITA",
            dram_fetch_pj: 0.0,
            wire_pj: self.ita_wire_pj,
            compute_pj: self.ita_mac_pj,
        }
    }

    /// Table II's headline: ITA vs INT8 GPU (paper: 49.6×).
    pub fn improvement_vs_int8(&self) -> f64 {
        self.gpu_int8().total_pj() / self.ita().total_pj()
    }
}

/// Paper Eq. 2: the DRAM energy floor per token for a weights-resident-in-
/// DRAM architecture (J/token).
pub fn dram_floor_j_per_token(params: u64, bits_per_param: u32, dram_pj_per_bit: f64) -> f64 {
    params as f64 * bits_per_param as f64 * dram_pj_per_bit * 1e-12
}

/// System power breakdown (paper Section VI-B1).
#[derive(Debug, Clone, Copy)]
pub struct SystemPower {
    pub device_w: f64,
    pub serdes_w: f64,
    pub host_cpu_w: (f64, f64),
    pub total_w: (f64, f64),
}

/// Device power at a given throughput: `ops/param × params × E_MAC × tok/s`
/// (reproduces the paper's 1.13 W @ 20 tok/s for 7B).
pub fn device_power_w(cfg: &ModelConfig, e: &EnergyParams, tok_per_s: f64) -> f64 {
    // Reproduces the paper's Section VI-B1 arithmetic verbatim:
    // 14e9 ops × 4.05 pJ × 20 tok/s = 1.13 W. (Strictly this double-counts
    // — 4.05 pJ is quoted *per MAC*, and ops = 2 × params — but it is the
    // paper's own accounting; flagged in EXPERIMENTS.md.)
    e.ops_per_param * cfg.params() as f64 * e.ita().total_pj() * 1e-12 * tok_per_s
}

/// Full system power including SerDes PHY and host attention CPU.
pub fn system_power(cfg: &ModelConfig, e: &EnergyParams, tok_per_s: f64) -> SystemPower {
    let device_w = device_power_w(cfg, e, tok_per_s);
    let serdes_w = 0.5;
    let host_cpu_w = (5.0, 10.0);
    SystemPower {
        device_w,
        serdes_w,
        host_cpu_w,
        total_w: (device_w + serdes_w + host_cpu_w.0, device_w + serdes_w + host_cpu_w.1),
    }
}

/// Leakage power for a die with `gates` NAND2-equivalents (paper Section
/// V-A: 10 nW/gate 28nm LP).
pub fn leakage_w(gates: f64, tech: &TechParams) -> f64 {
    gates * tech.leakage_w_per_gate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rows_match_paper() {
        let e = EnergyParams::default();
        let fp16 = e.gpu_fp16();
        assert!((fp16.dram_fetch_pj - 320.0).abs() < 1e-9);
        assert!((fp16.wire_pj - 80.0).abs() < 1e-9);
        assert!((fp16.total_pj() - 401.1).abs() < 0.01);

        let int8 = e.gpu_int8();
        assert!((int8.dram_fetch_pj - 160.0).abs() < 1e-9);
        assert!((int8.total_pj() - 201.0).abs() < 0.01);

        let ita = e.ita();
        assert!((ita.total_pj() - 4.05).abs() < 0.001);
    }

    #[test]
    fn headline_improvement_49_6x() {
        let e = EnergyParams::default();
        assert!((e.improvement_vs_int8() - 49.6).abs() < 0.1);
    }

    #[test]
    fn eq2_dram_floor_for_7b_fp16() {
        // paper: 14 GB × 8 b/B × 20 pJ/bit ≈ 2.24 J/token
        let j = dram_floor_j_per_token(14_000_000_000, 8, 20.0);
        assert!((j - 2.24).abs() < 0.01, "{j}");
    }

    #[test]
    fn device_power_matches_paper_1_13w() {
        // paper Section VI-B1: 1.13 W at 20 tok/s for the 7B device
        let cfg = &ModelConfig::LLAMA2_7B;
        let w = device_power_w(cfg, &EnergyParams::default(), 20.0);
        // our param accounting gives 6.6B (paper rounds to 7B): 1.07 W
        assert!((0.95..1.25).contains(&w), "{w}");
    }

    #[test]
    fn system_power_in_7_to_12_band() {
        let sp = system_power(&ModelConfig::LLAMA2_7B, &EnergyParams::default(), 20.0);
        assert!(sp.total_w.0 > 6.0 && sp.total_w.1 < 13.0, "{sp:?}");
    }

    #[test]
    fn derived_wire_energy_near_quoted() {
        // 32 bits × α·C·L·V² should land within ~2× of the paper's 4 pJ
        let d = EnergyParams::ita_wire_pj_derived(&TechParams::paper_28nm());
        assert!((1.5..9.0).contains(&d), "{d}");
    }

    #[test]
    fn leakage_small_vs_dynamic() {
        // a 100M-gate die leaks ~1 W — same order as the device budget,
        // flagged in EXPERIMENTS.md as a modeling observation
        let w = leakage_w(100e6, &TechParams::paper_28nm());
        assert!((w - 1.0).abs() < 1e-9);
    }
}
