//! Hybrid architecture model (paper Section VII-D): FFN weights hardwired,
//! QKV (+Wo) in on-chip SRAM — trading a slice of ITA's energy advantage
//! for limited model updatability / fine-tuning.

use crate::config::{ModelConfig, TechParams};

use super::EnergyParams;

/// Where each weight family lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Everything hardwired (pure ITA).
    FullItA,
    /// FFN hardwired, attention projections in on-chip SRAM (updatable).
    Hybrid,
    /// Everything in on-chip SRAM (updatable accelerator, no DRAM).
    FullSram,
}

/// Per-MAC energy for weights held in on-chip SRAM: the DRAM fetch is gone
/// but an SRAM read (~5 pJ for a wide 28nm macro access, amortized per
/// 4-bit weight) plus the ITA wire/compute remains.
pub const SRAM_READ_PJ_PER_WEIGHT: f64 = 5.0;

/// Fraction of device MACs in the FFN (vs QKV + Wo + head) for a topology.
pub fn ffn_mac_fraction(cfg: &ModelConfig) -> f64 {
    let d = cfg.d_model as f64;
    let f = cfg.d_ffn as f64;
    let l = cfg.n_layers as f64;
    let ffn = l * 3.0 * d * f;
    ffn / cfg.device_macs_per_token() as f64
}

/// Fraction of parameters that remain updatable under a placement.
pub fn updatable_fraction(cfg: &ModelConfig, placement: Placement) -> f64 {
    match placement {
        Placement::FullItA => 0.0,
        Placement::FullSram => 1.0,
        Placement::Hybrid => 1.0 - ffn_mac_fraction(cfg), // QKV/Wo/head share
    }
}

/// Average per-MAC energy under a placement.
pub fn energy_per_mac_pj(cfg: &ModelConfig, e: &EnergyParams, placement: Placement) -> f64 {
    let ita = e.ita().total_pj();
    let sram = ita + SRAM_READ_PJ_PER_WEIGHT;
    let ffn_frac = ffn_mac_fraction(cfg);
    match placement {
        Placement::FullItA => ita,
        Placement::FullSram => sram,
        Placement::Hybrid => ffn_frac * ita + (1.0 - ffn_frac) * sram,
    }
}

/// Fraction of the full-ITA improvement *factor* retained:
/// `(gpu/this) / (gpu/full) = full/this`. The paper's Section VII-D
/// "retains 70–80% of ITA's energy advantage" is this ratio.
pub fn advantage_retained(cfg: &ModelConfig, e: &EnergyParams, placement: Placement) -> f64 {
    e.ita().total_pj() / energy_per_mac_pj(cfg, e, placement)
}

/// Extra SRAM area for the updatable weights, mm².
pub fn sram_area_mm2(cfg: &ModelConfig, tech: &TechParams, placement: Placement) -> f64 {
    let d = cfg.d_model as f64;
    let l = cfg.n_layers as f64;
    let updatable_params = match placement {
        Placement::FullItA => 0.0,
        Placement::FullSram => cfg.params() as f64,
        Placement::Hybrid => l * 4.0 * d * d + cfg.vocab as f64 * d, // QKV+Wo+head
    };
    updatable_params * cfg.w_bits as f64 * tech.sram_um2_per_bit / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffn_fraction_matches_paper_band() {
        // paper Section II-B: FFN holds 60–67% of parameters; for Llama-2
        // topology the FFN MAC share is ~65%
        let f = ffn_mac_fraction(&ModelConfig::LLAMA2_7B);
        assert!((0.55..0.75).contains(&f), "{f}");
    }

    #[test]
    fn hybrid_retains_70_to_90_percent_advantage() {
        // paper Section VII-D: "retains 70–80% of ITA's energy advantage"
        let e = EnergyParams::default();
        let r = advantage_retained(&ModelConfig::LLAMA2_7B, &e, Placement::Hybrid);
        assert!((0.65..0.85).contains(&r), "{r}");
    }

    #[test]
    fn updatable_fraction_band() {
        // paper: QKV projections are 30–40% of parameters
        let u = updatable_fraction(&ModelConfig::LLAMA2_7B, Placement::Hybrid);
        assert!((0.25..0.45).contains(&u), "{u}");
    }

    #[test]
    fn placements_ordered_by_energy() {
        let e = EnergyParams::default();
        let cfg = &ModelConfig::LLAMA2_7B;
        let full = energy_per_mac_pj(cfg, &e, Placement::FullItA);
        let hybrid = energy_per_mac_pj(cfg, &e, Placement::Hybrid);
        let sram = energy_per_mac_pj(cfg, &e, Placement::FullSram);
        assert!(full < hybrid && hybrid < sram);
        // all placements remain far better than the GPU baseline
        assert!(sram < e.gpu_int8().total_pj() / 10.0);
    }

    #[test]
    fn hybrid_sram_area_reasonable() {
        // QKV+Wo+head of 7B at 0.3 µm²/bit SRAM: ~2.9 mm²/layer-ish total;
        // must be well below the hardwired die itself
        let tech = TechParams::paper_28nm();
        let a = sram_area_mm2(&ModelConfig::LLAMA2_7B, &tech, Placement::Hybrid);
        assert!(a > 100.0 && a < 4000.0, "{a}");
        assert_eq!(sram_area_mm2(&ModelConfig::LLAMA2_7B, &tech, Placement::FullItA), 0.0);
    }
}
