//! The ITA **device** (paper Section IV-B2): a stateless operator holding
//! every model weight, executing the linear projections. Two backends:
//!
//! * [`pjrt::PjrtDevice`] — the real artifact path: AOT-lowered HLO
//!   programs (containing the L1 Pallas kernels) executed via PJRT.
//! * [`sim::SimDevice`] — an independent pure-rust implementation of the
//!   identical arithmetic, used for differential testing and for running
//!   without artifacts.
//!
//! Both are *stateless between calls* exactly like the paper's ASIC: the
//! host owns every byte of dynamic state.

pub mod sim;
pub mod pjrt;

use anyhow::Result;

use crate::model::Mat;

/// Device geometry, mirrored from the artifact manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub vocab: usize,
}

/// Per-call device telemetry (interface accounting + modeled energy).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceStats {
    pub calls: u64,
    /// MACs executed (for the energy model).
    pub macs: u64,
    /// Rows of padding waste introduced by bucket rounding.
    pub padded_rows: u64,
}

/// The stateless ITA device interface. `h` is the hidden-state activation
/// matrix [B, d_model]; every method is a pure function of its inputs plus
/// the immutable weights.
///
/// Not `Send`: the PJRT client wraps raw pointers, so the server constructs
/// the device *inside* its worker thread (requests/results cross threads,
/// the device never does — matching the physical ASIC, which is bolted to
/// one PCIe slot).
pub trait ItaDevice {
    fn dims(&self) -> DeviceDims;

    /// Batch sizes the device accepts natively (compiled buckets). The
    /// engine may submit any batch ≤ max; the device pads internally.
    fn buckets(&self) -> &[usize];

    /// Pre-attention block: h → (q, k, v), each [B, d_model].
    fn qkv(&mut self, layer: usize, h: &Mat) -> Result<(Mat, Mat, Mat)>;

    /// Post-attention block: (h, attn_out) → h_next [B, d_model].
    fn ffn(&mut self, layer: usize, h: &Mat, attn: &Mat) -> Result<Mat>;

    /// Final norm + LM head: h → logits [B, vocab].
    fn logits(&mut self, h: &Mat) -> Result<Mat>;

    fn stats(&self) -> DeviceStats;
}

/// MACs for one full decode step at batch b (device-side linear algebra).
pub fn macs_per_step(dims: &DeviceDims, b: usize) -> u64 {
    let d = dims.d_model as u64;
    let f = dims.d_ffn as u64;
    let v = dims.vocab as u64;
    (dims.n_layers as u64 * (3 * d * d + d * d + 3 * d * f) + d * v) * b as u64
}
