//! Pure-rust reference device: mirrors `python/compile/model.py` operation
//! for operation (rmsnorm → per-row INT8 quantization → integer-valued
//! matmul → dequantize; SwiGLU FFN; tied LM head).
//!
//! Independent from both JAX *and* the PJRT runtime, so engine-level
//! differential tests (`rust/tests/differential.rs`) compare two disjoint
//! implementations end to end.

use anyhow::{ensure, Result};

use super::{DeviceDims, DeviceStats, ItaDevice};
use crate::model::{Mat, ModelWeights, QLinear};
use crate::quant::quant_act_row;
use crate::runtime::{Manifest, WeightStore};

/// Reference device over the fused-variant weight blobs.
pub struct SimDevice {
    dims: DeviceDims,
    weights: ModelWeights,
    buckets: Vec<usize>,
    stats: DeviceStats,
}

impl SimDevice {
    pub fn load(manifest: &Manifest, store: &WeightStore) -> Result<SimDevice> {
        Ok(SimDevice {
            dims: DeviceDims {
                d_model: manifest.d_model,
                n_layers: manifest.n_layers,
                d_ffn: manifest.d_ffn,
                vocab: manifest.vocab,
            },
            weights: ModelWeights::load(manifest, store)?,
            buckets: manifest.buckets.clone(),
            stats: DeviceStats::default(),
        })
    }

    /// Artifact-free device over [`ModelWeights::synthetic`]: identical
    /// arithmetic to the artifact path, weights generated deterministically
    /// from `seed`. This is the backbone of the deterministic test tier —
    /// fleet/scheduler/differential tests run from a clean checkout, no
    /// `make artifacts` required.
    pub fn synthetic(cfg: &crate::config::ModelConfig, buckets: Vec<usize>, seed: u64) -> SimDevice {
        assert!(!buckets.is_empty());
        SimDevice {
            dims: DeviceDims {
                d_model: cfg.d_model,
                n_layers: cfg.n_layers,
                d_ffn: cfg.d_ffn,
                vocab: cfg.vocab,
            },
            weights: ModelWeights::synthetic(cfg, seed),
            buckets,
            stats: DeviceStats::default(),
        }
    }

    /// Assemble a device from pre-built weights — the pipeline sharder's
    /// entry point: it generates ONE full synthetic weight set, slices a
    /// contiguous layer run per stage, and hands each slice here (so the
    /// stage arithmetic is bit-identical to the unsharded device's).
    /// `dims.n_layers` must match `weights.layers.len()`.
    pub fn from_weights(dims: DeviceDims, weights: ModelWeights, buckets: Vec<usize>) -> SimDevice {
        assert!(!buckets.is_empty());
        assert_eq!(dims.n_layers, weights.layers.len());
        SimDevice { dims, weights, buckets, stats: DeviceStats::default() }
    }

    pub fn weights(&self) -> &ModelWeights {
        &self.weights
    }

    /// rmsnorm(x) ⊙ g, mirroring ref.py (eps 1e-5, f32).
    fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
        let d = x.len() as f32;
        let var = x.iter().map(|v| v * v).sum::<f32>() / d;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..x.len() {
            out[i] = x[i] * inv * g[i];
        }
    }

    /// Quantized linear for one row: quantize, integer matmul, dequantize.
    fn qlinear_row(x: &[f32], lin: &QLinear, out: &mut [f32]) {
        let (xq, xs) = quant_act_row(x, 8);
        // acc_n = sum_k xq_k * w[k,n] — w is integer-valued f32
        out.fill(0.0);
        for (k, &q) in xq.iter().enumerate() {
            if q == 0 {
                continue;
            }
            let qf = q as f32;
            let row = &lin.w[k * lin.n..(k + 1) * lin.n];
            for n in 0..lin.n {
                out[n] += qf * row[n];
            }
        }
        for n in 0..lin.n {
            out[n] *= xs * lin.scale[n];
        }
    }

    fn silu(v: f32) -> f32 {
        v / (1.0 + (-v).exp())
    }
}

impl ItaDevice for SimDevice {
    fn dims(&self) -> DeviceDims {
        self.dims
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn qkv(&mut self, layer: usize, h: &Mat) -> Result<(Mat, Mat, Mat)> {
        ensure!(layer < self.dims.n_layers);
        ensure!(h.cols == self.dims.d_model);
        let d = self.dims.d_model;
        let lw = &self.weights.layers[layer];
        let mut q = Mat::zeros(h.rows, d);
        let mut k = Mat::zeros(h.rows, d);
        let mut v = Mat::zeros(h.rows, d);
        let mut x = vec![0.0; d];
        let mut qkv = vec![0.0; 3 * d];
        for r in 0..h.rows {
            Self::rmsnorm(h.row(r), &lw.g1, &mut x);
            Self::qlinear_row(&x, &lw.wqkv, &mut qkv);
            q.row_mut(r).copy_from_slice(&qkv[..d]);
            k.row_mut(r).copy_from_slice(&qkv[d..2 * d]);
            v.row_mut(r).copy_from_slice(&qkv[2 * d..]);
        }
        self.stats.calls += 1;
        self.stats.macs += (h.rows * d * 3 * d) as u64;
        Ok((q, k, v))
    }

    fn ffn(&mut self, layer: usize, h: &Mat, attn: &Mat) -> Result<Mat> {
        ensure!(layer < self.dims.n_layers);
        ensure!(h.rows == attn.rows && h.cols == attn.cols);
        let d = self.dims.d_model;
        let f = self.dims.d_ffn;
        let lw = &self.weights.layers[layer];
        let mut out = Mat::zeros(h.rows, d);
        let (mut x, mut o, mut a, mut b, mut fv) =
            (vec![0.0; d], vec![0.0; d], vec![0.0; f], vec![0.0; f], vec![0.0; d]);
        for r in 0..h.rows {
            // h += Wo(attn)
            Self::qlinear_row(attn.row(r), &lw.wo, &mut o);
            let hr: Vec<f32> = h.row(r).iter().zip(&o).map(|(a, b)| a + b).collect();
            // SwiGLU FFN on rmsnorm(h)
            Self::rmsnorm(&hr, &lw.g2, &mut x);
            Self::qlinear_row(&x, &lw.w1, &mut a);
            Self::qlinear_row(&x, &lw.w3, &mut b);
            let gated: Vec<f32> =
                a.iter().zip(&b).map(|(&av, &bv)| Self::silu(av) * bv).collect();
            Self::qlinear_row(&gated, &lw.w2, &mut fv);
            for i in 0..d {
                out.row_mut(r)[i] = hr[i] + fv[i];
            }
        }
        self.stats.calls += 1;
        self.stats.macs += (h.rows * (d * d + 3 * d * f)) as u64;
        Ok(out)
    }

    fn logits(&mut self, h: &Mat) -> Result<Mat> {
        let d = self.dims.d_model;
        let v = self.dims.vocab;
        let mut out = Mat::zeros(h.rows, v);
        let mut x = vec![0.0; d];
        for r in 0..h.rows {
            Self::rmsnorm(h.row(r), &self.weights.gf, &mut x);
            Self::qlinear_row(&x, &self.weights.we, out.row_mut(r));
        }
        self.stats.calls += 1;
        self.stats.macs += (h.rows * d * v) as u64;
        Ok(out)
    }

    fn stats(&self) -> DeviceStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Option<(Manifest, WeightStore)> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("MANIFEST.txt").exists() {
            eprintln!("skipping: artifacts/tiny not built");
            return None;
        }
        Some(crate::runtime::weights::load_artifacts(&dir).unwrap())
    }

    #[test]
    fn qkv_shapes_and_determinism() {
        let Some((m, s)) = tiny() else { return };
        let mut dev = SimDevice::load(&m, &s).unwrap();
        let h = Mat::new(2, 64, (0..128).map(|i| (i as f32 * 0.01).sin()).collect());
        let (q, k, v) = dev.qkv(0, &h).unwrap();
        assert_eq!((q.rows, q.cols), (2, 64));
        assert_eq!((k.rows, k.cols), (2, 64));
        assert_eq!((v.rows, v.cols), (2, 64));
        let (q2, _, _) = dev.qkv(0, &h).unwrap();
        assert_eq!(q.data, q2.data);
    }

    #[test]
    fn layers_differ() {
        let Some((m, s)) = tiny() else { return };
        let mut dev = SimDevice::load(&m, &s).unwrap();
        let h = Mat::new(1, 64, (0..64).map(|i| (i as f32 * 0.1).cos()).collect());
        let (q0, _, _) = dev.qkv(0, &h).unwrap();
        let (q1, _, _) = dev.qkv(1, &h).unwrap();
        assert_ne!(q0.data, q1.data);
    }

    #[test]
    fn ffn_residual_structure() {
        // with attn = 0 and h = 0, output must be 0 + FFN(norm(0))·... = 0
        // (rmsnorm(0)=0, silu(0)*0=0) — checks the residual wiring
        let Some((m, s)) = tiny() else { return };
        let mut dev = SimDevice::load(&m, &s).unwrap();
        let zero = Mat::zeros(1, 64);
        let out = dev.ffn(0, &zero, &zero).unwrap();
        for &v in &out.data {
            assert_eq!(v, 0.0);
        }
    }

    #[test]
    fn logits_shape() {
        let Some((m, s)) = tiny() else { return };
        let mut dev = SimDevice::load(&m, &s).unwrap();
        let h = Mat::new(3, 64, (0..192).map(|i| (i as f32 * 0.02).sin()).collect());
        let out = dev.logits(&h).unwrap();
        assert_eq!((out.rows, out.cols), (3, 258));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stats_accumulate() {
        let Some((m, s)) = tiny() else { return };
        let mut dev = SimDevice::load(&m, &s).unwrap();
        let h = Mat::zeros(1, 64);
        dev.qkv(0, &h).unwrap();
        dev.logits(&h).unwrap();
        let st = dev.stats();
        assert_eq!(st.calls, 2);
        assert!(st.macs > 0);
    }
}
