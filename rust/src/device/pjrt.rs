//! Artifact-backed ITA device: executes the AOT-lowered HLO programs
//! (containing the L1 Pallas kernels) on the PJRT CPU client.
//!
//! Batch handling: programs are compiled for fixed batch buckets; calls are
//! padded up to the smallest bucket ≥ B and outputs truncated — the
//! "padding bucket" policy whose waste the coordinator's batcher minimizes.

use anyhow::{ensure, Result};

use super::{DeviceDims, DeviceStats, ItaDevice};
use crate::model::Mat;
use crate::runtime::{Block, Manifest, PjrtRuntime, WeightStore};

/// PJRT-backed device.
pub struct PjrtDevice {
    rt: PjrtRuntime,
    dims: DeviceDims,
    buckets: Vec<usize>,
    variant: String,
    stats: DeviceStats,
    /// scratch for padded inputs (avoids per-call allocation)
    pad_a: Vec<f32>,
    pad_b: Vec<f32>,
}

impl PjrtDevice {
    /// Compile all programs of `variant` and upload weights.
    pub fn load(manifest: Manifest, store: &WeightStore, variant: &str) -> Result<PjrtDevice> {
        ensure!(
            manifest.variants.iter().any(|v| v == variant),
            "variant {variant} not in artifacts (have: {:?})",
            manifest.variants
        );
        let dims = DeviceDims {
            d_model: manifest.d_model,
            n_layers: manifest.n_layers,
            d_ffn: manifest.d_ffn,
            vocab: manifest.vocab,
        };
        let buckets = manifest.buckets.clone();
        let rt = PjrtRuntime::load(manifest, store)?;
        Ok(PjrtDevice {
            rt,
            dims,
            buckets,
            variant: variant.to_string(),
            stats: DeviceStats::default(),
            pad_a: Vec::new(),
            pad_b: Vec::new(),
        })
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }

    fn bucket_for(&self, rows: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= rows)
            .min()
            .ok_or_else(|| {
                anyhow::anyhow!("batch {rows} exceeds largest bucket {:?}", self.buckets)
            })
    }

    /// Pad `m` (rows×cols) into scratch to `bucket` rows; returns the slice.
    fn pad<'a>(scratch: &'a mut Vec<f32>, m: &Mat, bucket: usize) -> &'a [f32] {
        scratch.clear();
        scratch.resize(bucket * m.cols, 0.0);
        scratch[..m.rows * m.cols].copy_from_slice(&m.data);
        &scratch[..]
    }

    fn truncate(out: Vec<f32>, rows: usize, cols: usize) -> Mat {
        let mut data = out;
        data.truncate(rows * cols);
        Mat::new(rows, cols, data)
    }
}

impl ItaDevice for PjrtDevice {
    fn dims(&self) -> DeviceDims {
        self.dims
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn qkv(&mut self, layer: usize, h: &Mat) -> Result<(Mat, Mat, Mat)> {
        ensure!(h.cols == self.dims.d_model);
        let bucket = self.bucket_for(h.rows)?;
        let padded = Self::pad(&mut self.pad_a, h, bucket);
        let outs = self.rt.execute(
            layer as i32,
            Block::Qkv,
            &self.variant,
            bucket,
            &[(padded, &[bucket, self.dims.d_model])],
        )?;
        ensure!(outs.len() == 3);
        self.stats.calls += 1;
        self.stats.macs += (h.rows * self.dims.d_model * 3 * self.dims.d_model) as u64;
        self.stats.padded_rows += (bucket - h.rows) as u64;
        let d = self.dims.d_model;
        let mut it = outs.into_iter();
        Ok((
            Self::truncate(it.next().unwrap(), h.rows, d),
            Self::truncate(it.next().unwrap(), h.rows, d),
            Self::truncate(it.next().unwrap(), h.rows, d),
        ))
    }

    fn ffn(&mut self, layer: usize, h: &Mat, attn: &Mat) -> Result<Mat> {
        ensure!(h.rows == attn.rows && h.cols == attn.cols);
        let bucket = self.bucket_for(h.rows)?;
        let d = self.dims.d_model;
        // two scratch pads: h and attn
        let padded_h = Self::pad(&mut self.pad_a, h, bucket).to_owned();
        let padded_a = Self::pad(&mut self.pad_b, attn, bucket);
        let outs = self.rt.execute(
            layer as i32,
            Block::Ffn,
            &self.variant,
            bucket,
            &[(&padded_h, &[bucket, d]), (padded_a, &[bucket, d])],
        )?;
        ensure!(outs.len() == 1);
        self.stats.calls += 1;
        self.stats.macs +=
            (h.rows * (d * d + 3 * d * self.dims.d_ffn)) as u64;
        self.stats.padded_rows += (bucket - h.rows) as u64;
        Ok(Self::truncate(outs.into_iter().next().unwrap(), h.rows, d))
    }

    fn logits(&mut self, h: &Mat) -> Result<Mat> {
        let bucket = self.bucket_for(h.rows)?;
        let padded = Self::pad(&mut self.pad_a, h, bucket);
        let outs = self.rt.execute(
            -1,
            Block::Logits,
            &self.variant,
            bucket,
            &[(padded, &[bucket, self.dims.d_model])],
        )?;
        ensure!(outs.len() == 1);
        self.stats.calls += 1;
        self.stats.macs += (h.rows * self.dims.d_model * self.dims.vocab) as u64;
        self.stats.padded_rows += (bucket - h.rows) as u64;
        Ok(Self::truncate(outs.into_iter().next().unwrap(), h.rows, self.dims.vocab))
    }

    fn stats(&self) -> DeviceStats {
        self.stats
    }
}
