//! Model-extraction economics (paper Section VI-E, Fig 3): attack-vector
//! cost model and the economic-deterrent analysis. [`dpa`] simulates the
//! side-channel attack the paper flags as its main residual risk.

pub mod dpa;

/// An attack vector against deployed model weights.
#[derive(Debug, Clone)]
pub struct AttackVector {
    pub name: &'static str,
    /// Equipment cost range, $ (purchase).
    pub equipment_usd: (f64, f64),
    /// Facility-rental alternative, $/day (None if not rentable).
    pub rental_usd_per_day: Option<(f64, f64)>,
    /// Wall-clock effort range, days.
    pub time_days: (f64, f64),
    /// Required expertise.
    pub skill: Skill,
    /// Applies to which storage class.
    pub applies_to: Target,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Skill {
    Intermediate,
    Expert,
    PhdSemiconductor,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Weights in DRAM/flash behind a driver (GPU/NPU deployment).
    SoftwareReadable,
    /// Weights as metal/logic (ITA).
    PhysicalLogic,
}

/// The paper's attack inventory (Section VI-E2).
pub fn attack_vectors() -> Vec<AttackVector> {
    vec![
        AttackVector {
            name: "Software dump (nvidia-smi / torch serialization)",
            equipment_usd: (0.0, 2_000.0),
            rental_usd_per_day: None,
            time_days: (0.02, 0.1),
            skill: Skill::Intermediate,
            applies_to: Target::SoftwareReadable,
        },
        AttackVector {
            name: "Physical reverse engineering (delayer + SEM + netlist)",
            equipment_usd: (500_000.0, 2_000_000.0),
            rental_usd_per_day: Some((5_000.0, 10_000.0)),
            time_days: (90.0, 180.0),
            skill: Skill::PhdSemiconductor,
            applies_to: Target::PhysicalLogic,
        },
        AttackVector {
            name: "Side-channel (DPA / EM emanation)",
            equipment_usd: (70_000.0, 120_000.0),
            rental_usd_per_day: None,
            time_days: (30.0, 120.0),
            skill: Skill::Expert,
            applies_to: Target::PhysicalLogic,
        },
    ]
}

impl AttackVector {
    /// Cheapest total cost: min(buy, rent×days) + labor (at $1k/day expert,
    /// $2k/day PhD-level).
    pub fn min_cost_usd(&self) -> f64 {
        let labor_rate = match self.skill {
            Skill::Intermediate => 400.0,
            Skill::Expert => 1_000.0,
            Skill::PhdSemiconductor => 2_000.0,
        };
        let equip = match self.rental_usd_per_day {
            Some((lo, _)) => (lo * self.time_days.0).min(self.equipment_usd.0),
            None => self.equipment_usd.0,
        };
        equip + labor_rate * self.time_days.0
    }
}

/// Cheapest extraction cost against a storage class — Fig 3's bars.
pub fn extraction_floor_usd(target: Target) -> f64 {
    attack_vectors()
        .iter()
        .filter(|a| a.applies_to == target)
        .map(|a| a.min_cost_usd())
        .fold(f64::INFINITY, f64::min)
}

/// The paper's headline barrier ratio (≈25× in the text, 50–500× in the
/// economic-impact discussion depending on the baseline).
pub fn barrier_ratio() -> f64 {
    extraction_floor_usd(Target::PhysicalLogic) / extraction_floor_usd(Target::SoftwareReadable).max(2_000.0)
}

/// DPA countermeasures (paper Section VI-E2 limitations): masking + noise
/// injection cost model.
#[derive(Debug, Clone, Copy)]
pub struct Countermeasures {
    /// Die-area increase (paper: 10–20%).
    pub area_overhead: f64,
    /// Power increase (paper: 10–20%).
    pub power_overhead: f64,
    /// Added unit cost, $ (paper: $2–5).
    pub unit_cost_usd: f64,
}

pub const DPA_COUNTERMEASURES: Countermeasures =
    Countermeasures { area_overhead: 0.15, power_overhead: 0.15, unit_cost_usd: 3.5 };

/// Is extraction economically irrational for a model of a given training
/// cost? (Paper: deterrent when extraction ≥ some fraction of retraining.)
pub fn deterrent(training_cost_usd: f64, target: Target) -> bool {
    extraction_floor_usd(target) >= 0.01 * training_cost_usd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_software_floor_under_2k() {
        let f = extraction_floor_usd(Target::SoftwareReadable);
        assert!(f <= 2_000.0, "{f}");
    }

    #[test]
    fn fig3_ita_floor_at_least_50k() {
        let f = extraction_floor_usd(Target::PhysicalLogic);
        assert!(f >= 50_000.0, "{f}");
    }

    #[test]
    fn barrier_ratio_at_least_25x() {
        assert!(barrier_ratio() >= 25.0, "{}", barrier_ratio());
    }

    #[test]
    fn dpa_is_cheapest_physical_attack() {
        // the paper's own caveat: side channels may undercut the $50K
        // RE barrier — our model keeps DPA above it but flags the margin
        let vs = attack_vectors();
        let dpa = vs.iter().find(|a| a.name.contains("Side-channel")).unwrap();
        let re = vs.iter().find(|a| a.name.contains("reverse eng")).unwrap();
        assert!(dpa.min_cost_usd() < re.min_cost_usd() + re.equipment_usd.0);
    }

    #[test]
    fn deterrent_for_finetuned_models() {
        // $500K–5M fine-tuned models: ITA extraction is a real deterrent,
        // software dump is not
        assert!(deterrent(500_000.0, Target::PhysicalLogic));
        assert!(!deterrent(500_000.0, Target::SoftwareReadable));
    }

    #[test]
    fn countermeasure_bands() {
        let c = DPA_COUNTERMEASURES;
        assert!((0.10..=0.20).contains(&c.area_overhead));
        assert!((2.0..=5.0).contains(&c.unit_cost_usd));
    }
}
