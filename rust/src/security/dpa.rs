//! Differential/correlation power analysis simulator — quantifies the
//! paper's own side-channel caveat (Section VI-E2 Limitations): "because
//! weights are static, they produce repeatable power signatures".
//!
//! Model: the ITA MAC's dynamic power per cycle follows the Hamming weight
//! of its switching datapath, which for a hardwired weight `w` processing
//! activation `x` is proportional to `HW(w·x)` plus gaussian measurement
//! noise. A correlation power analysis (CPA) attacker who controls/observes
//! activations correlates hypothesis traces `HW(w̃·x_i)` for every candidate
//! w̃ against measured traces and picks the argmax.
//!
//! The simulator shows (a) clean traces leak an INT4 weight in tens of
//! traces, (b) the paper's masking/noise-injection countermeasure (+10-20%
//! area/power) pushes the required trace count up orders of magnitude —
//! turning "billions of parameters" into the months-of-collection effort
//! the paper's economics assume.

use crate::util::prng::Prng;

/// Leakage model parameters.
#[derive(Debug, Clone, Copy)]
pub struct DpaParams {
    /// Measurement noise sigma, in Hamming-weight units (scope + PDN).
    pub noise_sigma: f64,
    /// Amplitude randomization from the countermeasure (noise injection):
    /// extra sigma added when masking is enabled.
    pub countermeasure_sigma: f64,
    /// Random per-cycle power offset from clock randomization (masking).
    pub masked: bool,
}

impl DpaParams {
    pub fn unprotected() -> Self {
        DpaParams { noise_sigma: 1.0, countermeasure_sigma: 0.0, masked: false }
    }

    /// Paper Section VI-E2: logic masking + power noise injection.
    pub fn protected() -> Self {
        DpaParams { noise_sigma: 1.0, countermeasure_sigma: 8.0, masked: true }
    }
}

fn hamming_weight(v: i32) -> u32 {
    (v as u32).count_ones()
}

/// One measured power sample for the MAC computing `w * x`.
///
/// With `masked` the datapath is first-order boolean-masked: the register
/// holds `product ⊕ m` for a fresh random mask `m`, so the Hamming-weight
/// leak is statistically independent of the secret (the unmask happens in a
/// separate, balanced stage). This is the real mechanism behind "logic
/// masking" — additive noise alone only slows CPA by `σ²`.
pub fn power_sample(w: i8, x: i8, p: &DpaParams, rng: &mut Prng) -> f64 {
    let product = w as i32 * x as i32;
    let exposed = if p.masked {
        (product ^ (rng.next_u64() as i32)) & 0xFFFF
    } else {
        product & 0xFFFF
    };
    let mut sample = hamming_weight(exposed) as f64;
    sample += rng.normal() * p.noise_sigma;
    if p.masked {
        sample += rng.normal() * p.countermeasure_sigma;
    }
    sample
}

/// Collect `n` traces of the device MAC for known activations.
pub fn collect_traces(w: i8, n: usize, p: &DpaParams, rng: &mut Prng) -> (Vec<i8>, Vec<f64>) {
    let mut xs = Vec::with_capacity(n);
    let mut traces = Vec::with_capacity(n);
    for _ in 0..n {
        let x = rng.range_i64(-127, 127) as i8;
        xs.push(x);
        traces.push(power_sample(w, x, p, rng));
    }
    (xs, traces)
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// CPA attack: recover the hardwired weight from observed traces.
/// Returns (best candidate, correlation margin over runner-up).
pub fn cpa_attack(xs: &[i8], traces: &[f64]) -> (i8, f64) {
    let mut best = (0i8, f64::NEG_INFINITY);
    let mut second = f64::NEG_INFINITY;
    for cand in -8i16..=7 {
        let hyp: Vec<f64> = xs
            .iter()
            .map(|&x| hamming_weight((cand as i32 * x as i32) & 0xFFFF) as f64)
            .collect();
        let r = pearson(&hyp, traces);
        if r > best.1 {
            second = best.1;
            best = (cand as i8, r);
        } else if r > second {
            second = r;
        }
    }
    (best.0, best.1 - second.max(0.0))
}

/// Traces needed until CPA recovers `w` in `trials` consecutive attempts;
/// capped at `max_traces` (returns None if never reliable).
pub fn traces_to_break(w: i8, p: &DpaParams, max_traces: usize, seed: u64) -> Option<usize> {
    let mut n = 16;
    while n <= max_traces {
        let mut ok = true;
        for trial in 0..3 {
            let mut rng = Prng::new(seed ^ (n as u64) << 8 ^ trial);
            let (xs, tr) = collect_traces(w, n, p, &mut rng);
            if cpa_attack(&xs, &tr).0 != w {
                ok = false;
                break;
            }
        }
        if ok {
            return Some(n);
        }
        n *= 2;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpa_breaks_unprotected_mac_quickly() {
        // the paper's vulnerability, demonstrated: tens of traces suffice
        for w in [-7i8, -3, 2, 5, 7] {
            let n = traces_to_break(w, &DpaParams::unprotected(), 1 << 14, 42).unwrap();
            assert!(n <= 512, "w={w}: {n} traces");
        }
    }

    #[test]
    fn countermeasures_defeat_first_order_cpa() {
        // boolean masking decorrelates the leak entirely: first-order CPA
        // must NOT converge within a 64k-trace budget (a real attacker
        // needs second-order analysis — the "novel techniques" the paper's
        // Section VI-E2 alludes to)
        let w = 5i8;
        let clean = traces_to_break(w, &DpaParams::unprotected(), 1 << 16, 7).unwrap();
        assert!(clean <= 1024, "{clean}");
        let protected = traces_to_break(w, &DpaParams::protected(), 1 << 16, 7);
        assert!(protected.is_none(), "{protected:?}");
    }

    #[test]
    fn zero_weight_leaks_nothing() {
        // a pruned MAC has no gates — its "traces" are pure noise and CPA
        // margin collapses
        let mut rng = Prng::new(9);
        let (xs, tr) = collect_traces(0, 2048, &DpaParams::unprotected(), &mut rng);
        let (_, margin) = cpa_attack(&xs, &tr);
        assert!(margin < 0.2, "{margin}");
    }

    #[test]
    fn pearson_sane() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn full_model_extraction_economics() {
        // scale one-weight effort to a 7B model: even unprotected, serial
        // extraction of 6.6e9 weights at ~256 traces each and 1M traces/s
        // is weeks of physical access — matching the paper's claim that
        // billions of parameters (vs 128-bit keys) change DPA economics
        let per_weight = 256.0;
        let params = 6.6e9;
        let seconds = per_weight * params / 1e6;
        let days = seconds / 86_400.0;
        assert!(days > 10.0, "{days}");
    }
}
