# ITA reproduction — build entry points.
#
# The request path is pure rust (`cargo build/test/bench`); python runs only
# at compile time, producing the AOT artifact tree the PJRT tier loads.

ARTIFACTS ?= artifacts
CONFIGS   ?= tiny,demo-100m
PY        ?= python3

.PHONY: all build test test-registry-check bench-build bench-smoke smoke trace-check status-check docs docs-check artifacts clean-artifacts

all: build

build:
	cargo build --release

test:
	cargo test -q

# Cargo.toml sets `autotests = false` (tests live under rust/tests), so a
# test file without a [[test]] entry SILENTLY never runs. Fail loudly
# instead: every rust/tests/*.rs must be declared. CI runs this.
test-registry-check:
	@missing=0; \
	for f in rust/tests/*.rs; do \
		name=$$(basename $$f .rs); \
		grep -q "^name = \"$$name\"$$" Cargo.toml || { \
			echo "UNREGISTERED TEST: $$f has no [[test]] entry in Cargo.toml"; \
			missing=1; }; \
	done; \
	[ $$missing -eq 0 ] && echo "test registry OK: every rust/tests/*.rs is declared"

# Compile-check every bench target without running them (CI).
bench-build:
	cargo bench --no-run

# Run the end-to-end throughput bench (release/bench profile) and emit the
# machine-readable perf record BENCH_e2e.json (throughput, prefix-cache
# prefill skips, live-migration counts, pipeline-stage occupancy/link
# share, KV bytes-per-session under quantized cold pages + spill churn).
# Artifact-free: PJRT tiers skip.
bench-smoke:
	cargo bench --bench e2e_throughput

# Drive the fleet end-to-end on synthetic weights (artifact-free).
smoke:
	ITA_FLEET_CARTRIDGES=2 ITA_FLEET_REQUESTS=12 ITA_FLEET_TOKENS=8 \
		cargo run --release --example serve_fleet

# Observability smoke: serve with tracing on, emit the Perfetto timeline +
# metrics snapshot (JSON and Prometheus text), then schema-check both —
# including the rail that every request's queued+active spans sum to its
# reported E2E latency. See docs/observability.md.
trace-check:
	ITA_FLEET_CARTRIDGES=2 ITA_FLEET_REQUESTS=12 ITA_FLEET_TOKENS=8 \
		ITA_FLEET_TRACE=trace.json ITA_FLEET_METRICS=metrics.json \
		cargo run --release --example serve_fleet
	cargo run --release --example trace_check -- trace.json metrics.json

# Live status-surface smoke: boot serve_fleet with an ephemeral status
# port, SLOs declared, and tail-sampled tracing, then validate /status
# (ita-status-v1 JSON schema), /metrics (Prometheus text-format lint +
# counter monotonicity across two scrapes), and /trace (flight-recorder
# JSON) against the running fleet. See docs/observability.md.
status-check:
	cargo build --release --example serve_fleet --example status_check
	cargo run --release --example status_check

# Build the public API docs with warnings denied (broken intra-doc links
# and malformed examples fail). CI runs this; keep it green.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Fail on dead relative links in the markdown docs (README.md,
# rust/src/coordinator/README.md, docs/*.md). CI runs this next to the
# rustdoc deny-warnings pass, so doc restructures can't orphan a
# cross-reference.
docs-check:
	cargo run --release --example check_links

# AOT path: JAX device blocks -> HLO text + weight blobs under
# $(ARTIFACTS)/<config>/ (MANIFEST.txt, weights.bin, programs/*.hlo.txt).
# Needs jax; run from the repo root. The deterministic test tier does NOT
# need this — only the PJRT suites do (they skip when artifacts are absent).
artifacts:
	cd python && $(PY) -m compile.aot --out ../$(ARTIFACTS) --configs $(CONFIGS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
