//! Bench + regeneration of Table III (interface comparison) and the
//! Eq. 7–11 transfer accounting. `cargo bench --bench table3_interfaces`

use ita::config::ModelConfig;
use ita::interface::{token_latency, Link, TokenTraffic, HOST_ATTENTION_IDEAL_S};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let cfg = &ModelConfig::LLAMA2_7B;

    b.bench("table3/traffic_accounting", || {
        TokenTraffic::paper_mode(cfg).total_bytes()
    });
    b.bench("table3/latency_all_links", || {
        Link::ALL
            .iter()
            .map(|l| {
                token_latency(&TokenTraffic::paper_mode(cfg), l, HOST_ATTENTION_IDEAL_S)
                    .tokens_per_s()
            })
            .sum::<f64>()
    });

    ita::report::table3_report(None).print();
}
