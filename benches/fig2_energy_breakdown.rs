//! Bench + regeneration of Fig 2 (energy breakdown per parameter op).
//! `cargo bench --bench fig2_energy_breakdown`

use ita::energy::EnergyParams;
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let e = EnergyParams::default();
    b.bench("fig2/stacks", || {
        [e.gpu_fp16(), e.gpu_int8(), e.ita()].iter().map(|s| s.total_pj()).sum::<f64>()
    });

    ita::report::fig2_report().print();
}
