//! Host-attention microbenchmark — the paper's declared bottleneck
//! (Section VI-C2: 5 ms NPU-ideal vs 50–100 ms laptop CPU for 32 layers).
//!
//! Measures our rust `decode_attention` at the Llama-2-7B geometry
//! (32 heads × 128 dims) across context lengths, extrapolates the 32-layer
//! per-token cost, and feeds the measured figure back into the Table III
//! latency model. `cargo bench --bench host_attention`

use ita::host::attention::{decode_attention, AttentionConfig, AttentionScratch};
use ita::host::kv_cache::PagedKvCache;
use ita::util::benchkit::Bencher;
use ita::util::prng::Prng;

fn main() {
    let cfg = AttentionConfig::new(32, 128); // Llama-2-7B geometry
    let d = cfg.d_model();
    let mut bench = Bencher::default();
    let mut rng = Prng::new(7);

    let mut per_layer_at_512 = 0.0;
    for t in [64usize, 256, 512, 1024, 2048] {
        let mut cache = PagedKvCache::new(1, d, ita::coordinator::engine::PAGE_SIZE);
        let seq = cache.alloc_seq();
        for _ in 0..t {
            let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            cache.append(seq, 0, &k, &v).unwrap();
            cache.advance(seq).unwrap();
        }
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0; d];
        let mut scratch = AttentionScratch::new();
        let stats = bench.bench(&format!("attention/7b_geometry/ctx{t}"), || {
            decode_attention(&cfg, &cache, seq, 0, t, &q, &mut out, &mut scratch);
            out[0]
        });
        if t == 512 {
            per_layer_at_512 = stats.mean_ns / 1e9;
        }
    }

    // per-token host attention = 32 layers
    let per_token = per_layer_at_512 * 32.0;
    println!(
        "\nmeasured host attention (ctx 512, 32 layers): {:.1} ms/token \
         (paper: 5 ms NPU-ideal, 50-100 ms laptop CPU)",
        per_token * 1e3
    );
    ita::report::table3_report(Some(per_token)).print();
}
