//! Bench + regeneration of Table VII (single neuron, 64 parallel MACs).
//! `cargo bench --bench table7_fpga_neuron`

use ita::synth::fpga::{generic_neuron, hardwired_neuron, FpgaCosts};
use ita::synth::mac::sample_int4_weights;
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::default();
    let costs = FpgaCosts::default();
    let weights = sample_int4_weights(64, 42);

    b.bench("table7/map_generic_neuron", || generic_neuron(64, 8, 4, &costs).luts);
    b.bench("table7/map_hardwired_neuron", || hardwired_neuron(&weights, 8, &costs).luts);

    ita::report::table7_report().print();

    // sensitivity: the LUT reduction across 20 random weight draws
    let mut lo = f64::INFINITY;
    let mut hi = 0.0f64;
    for seed in 0..20 {
        let w = sample_int4_weights(64, seed);
        let t = ita::synth::fpga::table7(&w, &costs);
        lo = lo.min(t.lut_reduction);
        hi = hi.max(t.lut_reduction);
    }
    println!("\nLUT-reduction spread over 20 weight draws: {lo:.2}x – {hi:.2}x (paper: 1.81x)");
}
