//! Bench + regeneration of Table V (cost vs volume, NRE amortization).
//! `cargo bench --bench table5_cost_volume`

use ita::area::{estimate, Routing};
use ita::config::{ModelConfig, TechParams};
use ita::cost::{cost_at_volume, dies_per_wafer, unit_cost, TABLE5_VOLUMES};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let tech = TechParams::paper_28nm();

    b.bench("table5/full_cost_stack", || {
        let est = estimate(&ModelConfig::LLAMA2_7B, &tech, Routing::Optimistic);
        let u = unit_cost(&est, &tech);
        TABLE5_VOLUMES
            .iter()
            .map(|&v| cost_at_volume(&u, &tech, v).unit_total)
            .sum::<f64>()
    });

    ita::report::table5_report().print();

    println!(
        "\ndies/wafer at the paper's 520 mm²: {:.0} (paper ≈115, classic edge-loss formula)",
        dies_per_wafer(520.0, 300.0)
    );
}
