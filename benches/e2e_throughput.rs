//! End-to-end serving throughput over the PJRT device — the whole-stack
//! number §Perf tracks. Runs the tiny cartridge always; the demo-100m
//! config when its artifacts exist (skips quietly otherwise).
//! `cargo bench --bench e2e_throughput`

use std::path::PathBuf;
use std::time::Instant;

use ita::coordinator::engine::Engine;
use ita::coordinator::request::GenRequest;
use ita::coordinator::scheduler::{Scheduler, SchedulerOpts};
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::host::embedding::EmbeddingTable;
use ita::runtime::weights::load_artifacts;

fn bench_config(name: &str, n_requests: usize, max_tokens: usize) -> Option<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    if !dir.join("MANIFEST.txt").exists() {
        eprintln!("skip {name}: artifacts missing");
        return None;
    }
    let (m, s) = load_artifacts(&dir).ok()?;
    let n_heads = m.n_heads;
    let sim = SimDevice::load(&m, &s).ok()?;
    let emb = EmbeddingTable::new(sim.weights().emb.clone());
    let t_compile = Instant::now();
    let dev = PjrtDevice::load(m, &s, "fused").ok()?;
    let compile_s = t_compile.elapsed().as_secs_f64();

    let engine = Engine::new(Box::new(dev), emb, n_heads);
    let mut sched = Scheduler::new(engine, SchedulerOpts::default());
    for i in 0..n_requests {
        sched.submit(GenRequest {
            id: i as u64,
            prompt: "end to end throughput".into(),
            max_new_tokens: max_tokens,
            sampling: ita::host::sampling::SamplingParams::greedy(),
            stop_at_eos: false,
        });
    }
    let t0 = Instant::now();
    let results = sched.run_to_completion().ok()?;
    let wall = t0.elapsed().as_secs_f64();
    let m = sched.metrics();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "bench e2e/{name:<22} {:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (compile {compile_s:.1}s, batch_waste {:.1}%, {:.1} MB interface)",
        tokens,
        tokens as f64 / wall,
        m.batch_waste * 100.0,
        m.interface_bytes as f64 / 1e6,
    );
    Some(())
}

fn main() {
    bench_config("tiny", 16, 32);
    // saturate the largest compiled bucket: at the DRAM-streaming roofline
    // every extra row in a weight sweep is almost free (§Perf iteration 5)
    bench_config("demo-100m", 16, 16);
}
