//! End-to-end serving throughput — the whole-stack number §Perf tracks.
//!
//! Two tiers:
//! * **fleet sweep** (always runs): synthetic SimDevice cartridges, sweeping
//!   cartridge count to show host-side scale-out of the stateless device
//!   (1 → N cartridges behind the shared admission queue).
//! * **artifact tier**: the PJRT tiny/demo-100m cartridges when artifacts
//!   and real bindings exist (skips quietly otherwise).
//!
//! `cargo bench --bench e2e_throughput`

use std::path::PathBuf;
use std::time::Instant;

use ita::config::ModelConfig;
use ita::coordinator::engine::Engine;
use ita::coordinator::fleet::Fleet;
use ita::coordinator::request::GenRequest;
use ita::coordinator::scheduler::{Scheduler, SchedulerOpts};
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::host::embedding::EmbeddingTable;
use ita::runtime::weights::load_artifacts;

/// Sweep cartridge count over a fixed workload; prints aggregate tok/s and
/// the per-cartridge request split.
fn bench_fleet(cartridges: usize, n_requests: usize, max_tokens: usize) {
    let fleet = Fleet::start(
        cartridges,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
    )
    .expect("fleet start");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            fleet.submit(GenRequest {
                id: i as u64,
                prompt: "end to end fleet throughput".into(),
                max_new_tokens: max_tokens,
                sampling: ita::host::sampling::SamplingParams::greedy(),
                stop_at_eos: false,
            })
        })
        .collect();
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.wait().expect("request completes").tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = fleet.shutdown().expect("fleet shutdown");
    let split: Vec<u64> =
        m.cartridges.iter().map(|c| c.serving.requests_completed).collect();
    println!(
        "bench e2e/fleet-sim x{cartridges:<2} {tokens:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (split {split:?}, requeued {}, {:.1} MB interface)",
        tokens as f64 / wall,
        m.requeued_requests,
        m.aggregate().interface_bytes as f64 / 1e6,
    );
}

fn bench_config(name: &str, n_requests: usize, max_tokens: usize) -> Option<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    if !dir.join("MANIFEST.txt").exists() {
        eprintln!("skip {name}: artifacts missing");
        return None;
    }
    let (m, s) = load_artifacts(&dir).ok()?;
    let n_heads = m.n_heads;
    let sim = SimDevice::load(&m, &s).ok()?;
    let emb = EmbeddingTable::new(sim.weights().emb.clone());
    let t_compile = Instant::now();
    let dev = match PjrtDevice::load(m, &s, "fused") {
        Ok(d) => d,
        Err(e) => {
            eprintln!("skip {name}: {e:#}");
            return None;
        }
    };
    let compile_s = t_compile.elapsed().as_secs_f64();

    let engine = Engine::new(Box::new(dev), emb, n_heads);
    let mut sched = Scheduler::new(engine, SchedulerOpts::default());
    for i in 0..n_requests {
        sched.submit(GenRequest {
            id: i as u64,
            prompt: "end to end throughput".into(),
            max_new_tokens: max_tokens,
            sampling: ita::host::sampling::SamplingParams::greedy(),
            stop_at_eos: false,
        });
    }
    let t0 = Instant::now();
    let results = sched.run_to_completion().ok()?;
    let wall = t0.elapsed().as_secs_f64();
    let m = sched.metrics();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "bench e2e/{name:<22} {:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (compile {compile_s:.1}s, batch_waste {:.1}%, {:.1} MB interface)",
        tokens,
        tokens as f64 / wall,
        m.batch_waste * 100.0,
        m.interface_bytes as f64 / 1e6,
    );
    Some(())
}

fn main() {
    // cartridge-count sweep: the stateless device makes scale-out a pure
    // host-coordination exercise — aggregate throughput should grow until
    // host attention threads saturate the machine
    for cartridges in [1usize, 2, 4] {
        bench_fleet(cartridges, 32, 16);
    }
    bench_config("tiny", 16, 32);
    // saturate the largest compiled bucket: at the DRAM-streaming roofline
    // every extra row in a weight sweep is almost free (§Perf iteration 5)
    bench_config("demo-100m", 16, 16);
}
