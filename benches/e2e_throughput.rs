//! End-to-end serving throughput — the whole-stack number §Perf tracks.
//!
//! The tiers:
//! * **fleet sweep** (always runs): synthetic SimDevice cartridges, sweeping
//!   cartridge count to show host-side scale-out of the stateless device
//!   (1 → N cartridges behind the shared admission queue).
//! * **shared-prefix sweep** (always runs): 32 requests behind one long
//!   system prompt, radix prefix cache off vs on (and a prefix-affinity
//!   fleet), reporting the prefill-token reduction from KV reuse.
//! * **migration sweep** (always runs): a skewed long/short workload under
//!   [`Rebalance`] dispatch, reporting live KV migrations and
//!   checkpoint-restored tokens.
//! * **mixed prefill+decode sweep** (always runs): steady decode streams hit
//!   by a multi-kilotoken prompt mid-stream, run-to-completion vs chunked
//!   prefill — the decode inter-token gap histogram (`itl_step`) shows the
//!   stall chunking removes.
//! * **pipeline sweep** (always runs): the same decode workload on a
//!   K-stage pipelined cartridge group (K ∈ {1, 2, 4}), reporting tok/s,
//!   per-stage occupancy, and the modeled link-transfer share.
//! * **tracing overhead** (always runs): one decode workload with the
//!   request-lifecycle trace recorder off vs on — the off path must stay
//!   free (≤1% tok/s delta is the acceptance target).
//! * **live telemetry** (always runs): the same front-door workload at the
//!   three observability postures — plane off, production (SLOs declared +
//!   tail-sampled always-on tracing), and full post-mortem tracing — with
//!   the tail-sampled tok/s overhead recorded (≤3% is the acceptance
//!   target), plus a per-tenant overload storm whose labeled series give
//!   each `(tenant, class)` lane its own shed rate and admitted-ITL tail.
//!   The contract under test is `docs/observability.md`.
//! * **kv capacity sweep** (always runs): peak resident KV bytes per
//!   session under fp32/int8/int4 cold-page encodings (the
//!   sessions-per-arena win of quantized cold pages), plus fp32/int8 legs
//!   under a deliberately tight byte budget with the disk spill tier
//!   holding the workload together. See `docs/kv-memory-tiers.md`.
//! * **overload sweep** (always runs): bursty arrival storms at 10× and
//!   100× the serially-measured service rate through the streaming front
//!   door, baseline (admit everything) vs admission-controlled (ITL target
//!   + queue-wait budget + adaptive prefill) — reporting the p99 inter-token
//!   latency of *admitted* requests, the shed rate, and goodput. The
//!   contract under test is `docs/serving-front-door.md`.
//! * **artifact tier**: the PJRT tiny/demo-100m cartridges when artifacts
//!   and real bindings exist (skips quietly otherwise).
//!
//! `cargo bench --bench e2e_throughput`
//!
//! Besides the human-readable report, the run writes a machine-readable
//! perf record to `BENCH_e2e.json` (override with `ITA_BENCH_JSON=path`;
//! CI uploads it as a workflow artifact so the perf trajectory is
//! queryable across PRs).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use ita::config::ModelConfig;
use ita::coordinator::engine::Engine;
use ita::coordinator::fleet::{Fleet, LeastLoaded, PrefixAffinity, Rebalance};
use ita::coordinator::frontdoor::{FrontDoor, FrontDoorOpts, QoS, SubmitError};
use ita::coordinator::metrics::ServingMetrics;
use ita::coordinator::pipeline::PipelineEngine;
use ita::coordinator::request::GenRequest;
use ita::coordinator::scheduler::{KvMemOpts, Scheduler, SchedulerOpts};
use ita::coordinator::spec::{CartridgeEngines, SpecOpts};
use ita::coordinator::telemetry::SloSpec;
use ita::coordinator::workload::{self, Arrivals, WorkloadSpec};
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::host::embedding::EmbeddingTable;
use ita::host::kv_cache::{KvQuantTag, KvSnapshot};
use ita::host::sampling::SamplingParams;
use ita::runtime::weights::load_artifacts;
use ita::util::json::{json_array, Json};

/// The observability keys every sweep carries (schema v5): modeled
/// joules/token from the device MAC ledger and the admission queue-wait
/// percentiles. See `docs/observability.md` for the methodology.
fn put_observability(j: &mut Json, m: &ServingMetrics) {
    j.float("joules_per_token", m.joules_per_token());
    j.float("queue_wait_p50_ms", m.queue_wait.percentile(50.0) * 1e3);
    j.float("queue_wait_p99_ms", m.queue_wait.percentile(99.0) * 1e3);
}

/// Sweep cartridge count over a fixed workload; prints aggregate tok/s and
/// the per-cartridge request split. Returns the JSON record for the sweep.
fn bench_fleet(cartridges: usize, n_requests: usize, max_tokens: usize) -> String {
    let fleet = Fleet::start(
        cartridges,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
    )
    .expect("fleet start");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            fleet.submit(GenRequest {
                id: i as u64,
                prompt: "end to end fleet throughput".into(),
                max_new_tokens: max_tokens,
                sampling: ita::host::sampling::SamplingParams::greedy(),
                stop_at_eos: false,
            })
        })
        .collect();
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.wait().expect("request completes").tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = fleet.shutdown().expect("fleet shutdown");
    let split: Vec<u64> =
        m.cartridges.iter().map(|c| c.serving.requests_completed).collect();
    println!(
        "bench e2e/fleet-sim x{cartridges:<2} {tokens:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (split {split:?}, requeued {}, {:.1} MB interface)",
        tokens as f64 / wall,
        m.requeued_requests,
        m.aggregate().interface_bytes as f64 / 1e6,
    );
    let mut j = Json::default();
    j.num("cartridges", cartridges);
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.float("tok_per_s", tokens as f64 / wall);
    j.num("requeued", m.requeued_requests);
    j.num("interface_bytes", m.aggregate().interface_bytes);
    put_observability(&mut j, &m.aggregate());
    j.encode()
}

/// A skewed long/short workload under [`Rebalance`] dispatch: least-loaded
/// parks the long decodes on one cartridge; once the shorts drain, the
/// spread triggers live KV migrations onto the idle one. Returns the JSON
/// record (migrations, checkpoint-restored tokens, throughput).
fn bench_migration(n_requests: usize, long_tokens: usize, short_tokens: usize) -> String {
    let fleet = Fleet::with_dispatch(
        2,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
        Box::new(Rebalance::new(Box::new(LeastLoaded))),
    )
    .expect("fleet start");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let long = i % 2 == 0;
            fleet.submit(GenRequest {
                id: i as u64,
                prompt: if long {
                    format!("long decode request {i}")
                } else {
                    format!("short request {i}")
                },
                max_new_tokens: if long { long_tokens } else { short_tokens },
                sampling: SamplingParams::greedy(),
                stop_at_eos: false,
            })
        })
        .collect();
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.wait().expect("request completes").tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = fleet.shutdown().expect("fleet shutdown");
    let agg = m.aggregate();
    println!(
        "bench e2e/migration x2   {tokens:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         ({} live migrations, {} KV rows restored, {} resumed)",
        tokens as f64 / wall,
        m.migrations,
        agg.restored_tokens,
        agg.resumed_requests,
    );
    let mut j = Json::default();
    j.num("cartridges", 2);
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.float("tok_per_s", tokens as f64 / wall);
    j.num("migrations", m.migrations);
    j.num("checkpoint_resumes", m.checkpoint_resumes);
    j.num("resumed_requests", agg.resumed_requests);
    j.num("restored_tokens", agg.restored_tokens);
    j.num("migrated_out", agg.migrated_out);
    put_observability(&mut j, &agg);
    j.encode()
}

/// 32 requests behind one long shared system prompt: the production shape
/// the radix prefix cache targets. Runs single-cartridge with the cache
/// off/on, then a 2-cartridge fleet under prefix-affinity dispatch, and
/// reports the prefill-token reduction (`prefill_skipped_tokens`). Returns
/// the JSON record.
fn bench_shared_prefix(n_requests: usize, max_tokens: usize) -> String {
    let system = "System: you are a careful assistant for the immutable tensor \
        architecture; answer from the paper, cite sections, refuse to speculate about \
        dynamic state, and keep every reply under one hundred tokens. "
        .repeat(2);
    let reqs: Vec<GenRequest> = (0..n_requests)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: format!("{system}Q{i:02}"),
            max_new_tokens: max_tokens,
            sampling: SamplingParams::greedy(),
            stop_at_eos: false,
        })
        .collect();

    let run_sched = |cache_pages: usize| {
        let opts = SchedulerOpts { prefix_cache_pages: cache_pages, ..SchedulerOpts::default() };
        let mut sched =
            Scheduler::new(Engine::synthetic(&ModelConfig::TINY, 0x17A), opts);
        for r in &reqs {
            sched.submit(r.clone());
        }
        let t0 = Instant::now();
        let results = sched.run_to_completion().expect("run");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        (tokens, wall, sched.metrics())
    };

    let (tok_off, wall_off, m_off) = run_sched(0);
    let (tok_on, wall_on, m_on) = run_sched(SchedulerOpts::default().prefix_cache_pages);
    assert_eq!(tok_off, tok_on, "prefix cache changed outputs");
    let total_prompt = m_on.tokens_prefilled + m_on.prefill_skipped_tokens;
    let reduction = m_on.prefill_skipped_tokens as f64 / total_prompt.max(1) as f64;
    println!(
        "bench e2e/shared-prefix  cache off: {:>6} prefill tokens in {wall_off:>6.2}s = \
         {:>7.1} tok/s total",
        m_off.tokens_prefilled,
        (tok_off + m_off.tokens_prefilled as usize) as f64 / wall_off,
    );
    println!(
        "bench e2e/shared-prefix  cache on : {:>6} prefill tokens ({} skipped, {:.0}% reduction) \
         in {wall_on:>6.2}s = {:>7.1} tok/s total",
        m_on.tokens_prefilled,
        m_on.prefill_skipped_tokens,
        reduction * 100.0,
        (tok_on + m_on.tokens_prefilled as usize) as f64 / wall_on,
    );

    // prefix-affinity fleet: same workload over 2 cartridges; the router
    // keeps the shared prefix on one cartridge's thread-local cache
    let fleet = Fleet::with_dispatch(
        2,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
        Box::new(PrefixAffinity::new()),
    )
    .expect("fleet start");
    let t0 = Instant::now();
    let handles: Vec<_> = reqs.iter().map(|r| fleet.submit(r.clone())).collect();
    let mut tokens = 0usize;
    for h in handles {
        tokens += h.wait().expect("request completes").tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = fleet.shutdown().expect("fleet shutdown");
    let agg = m.aggregate();
    let split: Vec<u64> =
        m.cartridges.iter().map(|c| c.serving.requests_completed).collect();
    println!(
        "bench e2e/shared-prefix  affinity x2: {tokens:>5} tokens in {wall:>6.2}s, \
         {} prefill skipped (split {split:?})",
        agg.prefill_skipped_tokens,
    );
    let mut j = Json::default();
    j.num("requests", n_requests);
    j.num("prefill_tokens_cache_off", m_off.tokens_prefilled);
    j.num("prefill_tokens_cache_on", m_on.tokens_prefilled);
    j.num("prefill_skipped_tokens", m_on.prefill_skipped_tokens);
    j.float("skip_fraction", reduction);
    j.float("wall_s_cache_off", wall_off);
    j.float("wall_s_cache_on", wall_on);
    j.num("affinity_fleet_prefill_skipped", agg.prefill_skipped_tokens);
    put_observability(&mut j, &m_on);
    j.encode()
}

/// The zero-cost-when-disabled rail: run one decode-heavy scheduler
/// workload with tracing off (the default) and again with a live trace
/// ring, and record the tok/s delta. The disabled path is a single bool
/// load per wave, so the delta should be wall-clock noise (the acceptance
/// target is ≤1%); the record keeps it measurable across PRs rather than
/// asserted in-process, where a loaded CI runner would flake.
fn bench_tracing_overhead(n_requests: usize, max_tokens: usize) -> String {
    let run = |trace_capacity: usize| {
        let opts = SchedulerOpts { trace_capacity, ..SchedulerOpts::default() };
        let mut sched = Scheduler::new(Engine::synthetic(&ModelConfig::TINY, 0x17A), opts);
        for i in 0..n_requests {
            let mut r =
                GenRequest::greedy(i as u64, &format!("traced decode stream {i}"), max_tokens);
            r.stop_at_eos = false;
            sched.submit(r);
        }
        let t0 = Instant::now();
        let results = sched.run_to_completion().expect("run");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        (tokens as f64 / wall, tokens)
    };
    let (off, tokens) = run(0);
    let (on, _) = run(1 << 16);
    let delta_pct = (off - on) / off * 100.0;
    println!(
        "bench e2e/trace-overhead {tokens:>5} tokens: {off:>7.1} tok/s untraced, \
         {on:>7.1} tok/s traced ({delta_pct:+.2}% delta)"
    );
    let mut j = Json::default();
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("tok_per_s_untraced", off);
    j.float("tok_per_s_traced", on);
    j.float("delta_pct", delta_pct);
    j.encode()
}

/// Live-observability-plane cost: the same streaming front-door workload
/// at the three postures — plane effectively off (no SLOs, no tracing),
/// production (SLOs declared + tail-sampled always-on tracing under a hard
/// event budget), and full post-mortem tracing (SLOs + retain-everything
/// sink). The tail-sampled tok/s overhead against the off baseline is the
/// ≤3% acceptance number; the record keeps it measurable across PRs. Then
/// a per-tenant overload storm through a tight queue budget: the labeled
/// series give each `(tenant, class)` lane its own shed rate, admitted-ITL
/// tail, and queue-wait percentiles, with any burn-rate alert state at
/// shutdown recorded alongside. Returns the JSON record.
fn bench_live_telemetry(n_requests: usize, max_tokens: usize) -> String {
    // regime = (label, trace_capacity, tail_budget, SLOs declared)
    let regimes: [(&str, usize, Option<usize>, bool); 3] = [
        ("off", 0, None, false),
        ("tail_sampled", 1 << 14, Some(4096), true),
        ("full", 1 << 14, None, true),
    ];
    let mut records = Vec::new();
    let mut rates = Vec::new();
    for (label, trace_capacity, tail, slo) in regimes {
        let opts = SchedulerOpts { trace_capacity, ..SchedulerOpts::default() };
        let slo_spec = if slo {
            Some(SloSpec { p99_itl_s: Some(0.05), availability: Some(0.99), ..SloSpec::default() })
        } else {
            None
        };
        let door_opts =
            FrontDoorOpts { slo: slo_spec, trace_tail_budget: tail, ..FrontDoorOpts::default() };
        let front = FrontDoor::start(
            2,
            |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
            opts,
            door_opts,
        )
        .expect("front door start");
        let t0 = Instant::now();
        let streams: Vec<_> = (0..n_requests)
            .map(|i| {
                let mut r = GenRequest::greedy(
                    i as u64,
                    &format!("telemetry regime stream {i}"),
                    max_tokens,
                );
                r.stop_at_eos = false;
                let lane = QoS::default().for_tenant((i % 3) as u64 + 1, 1);
                front.submit_with(r, lane).expect("uncontended submit")
            })
            .collect();
        let mut tokens = 0usize;
        for s in streams {
            tokens += s.wait().expect("request completes").tokens.len();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = front.shutdown().expect("fleet shutdown");
        let tok_per_s = tokens as f64 / wall;
        rates.push(tok_per_s);
        println!(
            "bench e2e/live-telemetry {label:<12} {tokens:>5} tokens in {wall:>6.2}s = \
             {tok_per_s:>7.1} tok/s  ({} tenant series, {} trace events dropped)",
            m.tenants.len(),
            m.trace_dropped_total,
        );
        let mut j = Json::default();
        j.str("regime", label);
        j.num("requests", n_requests);
        j.num("tokens", tokens);
        j.float("wall_s", wall);
        j.float("tok_per_s", tok_per_s);
        j.num("tenant_series", m.tenants.len());
        j.num("trace_dropped_total", m.trace_dropped_total);
        records.push(j.encode());
    }
    let tail_overhead_pct = (rates[0] - rates[1]) / rates[0] * 100.0;
    let full_overhead_pct = (rates[0] - rates[2]) / rates[0] * 100.0;
    println!(
        "bench e2e/live-telemetry tail-sampled overhead {tail_overhead_pct:+.2}% vs off \
         (acceptance ≤3%), full tracing {full_overhead_pct:+.2}%"
    );

    // per-tenant overload storm: one cartridge, two decode slots, a tight
    // queue budget — three (tenant, class) lanes share the door and the
    // labeled series split the storm's damage per lane
    let opts = SchedulerOpts { max_active: 2, ..SchedulerOpts::default() };
    let door_opts = FrontDoorOpts {
        queue_budget_s: Some(0.05),
        slo: Some(SloSpec { availability: Some(0.99), ..SloSpec::default() }),
        ..FrontDoorOpts::default()
    };
    let front = FrontDoor::start(
        1,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        opts,
        door_opts,
    )
    .expect("front door start");
    let lanes = [
        QoS::interactive().for_tenant(1, 1),
        QoS::default().for_tenant(2, 1),
        QoS::batch().for_tenant(3, 1),
    ];
    // serial warmup teaches the admission controller its drain rate
    for i in 0..4u64 {
        let mut r = GenRequest::greedy(1000 + i, "warm the estimator", 8);
        r.stop_at_eos = false;
        front.submit_with(r, lanes[1]).expect("warmup admits").wait().expect("completes");
    }
    let offered = 48usize;
    let t0 = Instant::now();
    let mut streams = Vec::new();
    let mut shed = 0usize;
    for i in 0..offered {
        let mut r = GenRequest::greedy(i as u64, &format!("tenant storm {i}"), 16);
        r.stop_at_eos = false;
        match front.submit_with(r, lanes[i % 3]) {
            Ok(s) => streams.push(s),
            Err(SubmitError::Overloaded { .. }) => shed += 1,
            Err(SubmitError::Closed) => panic!("fleet closed mid-bench"),
        }
    }
    let admitted = streams.len();
    for s in streams {
        s.wait().expect("admitted request completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = front.shutdown().expect("fleet shutdown");
    let mut rows = Vec::new();
    for t in &m.tenants {
        println!(
            "bench e2e/tenant-overload t{} {:<11} admitted {:>2}, shed {:>2}, \
             itl p99 {:>7.2} ms, wait p99 {:>7.2} ms",
            t.tenant,
            t.class,
            t.admitted,
            t.shed,
            t.itl.percentile(99.0) * 1e3,
            t.queue_wait.percentile(99.0) * 1e3,
        );
        let mut r = Json::default();
        r.num("tenant", t.tenant);
        r.str("class", t.class);
        r.num("admitted", t.admitted);
        r.num("shed", t.shed);
        r.num("completed", t.requests_completed);
        r.float("itl_p99_ms", t.itl.percentile(99.0) * 1e3);
        r.float("queue_wait_p99_ms", t.queue_wait.percentile(99.0) * 1e3);
        rows.push(r.encode());
    }
    let mut alerts = Vec::new();
    for a in &m.alerts {
        let mut r = Json::default();
        r.str("slo", a.slo);
        r.str("state", a.state.name());
        r.float("fast_burn", a.fast_burn);
        r.float("slow_burn", a.slow_burn);
        alerts.push(r.encode());
    }

    let mut j = Json::default();
    j.put("regimes", json_array(&records));
    j.float("tail_overhead_pct", tail_overhead_pct);
    j.float("full_overhead_pct", full_overhead_pct);
    let mut storm = Json::default();
    storm.num("offered", offered);
    storm.num("admitted", admitted);
    storm.num("shed", shed);
    storm.float("wall_s", wall);
    storm.put("tenants", json_array(&rows));
    storm.put("alerts", json_array(&alerts));
    j.put("tenant_overload", storm.encode());
    j.encode()
}

/// Long-prefill interference: 4 steady decode streams, then one
/// `long_prompt_tokens`-token prompt arrives mid-stream. Under
/// run-to-completion scheduling (`chunk_tokens = 0`) the whole prefill runs
/// inside one scheduling iteration and every stream's next token waits for
/// it; under chunked prefill the per-iteration stall is bounded by the
/// budget. The decode inter-token gap histogram (`itl_step`) makes the
/// difference visible: p50 barely moves, p99/max collapse. Returns the JSON
/// record.
fn bench_mixed_prefill_decode(chunk_tokens: usize, long_prompt_tokens: usize) -> String {
    let opts = SchedulerOpts { prefill_chunk_tokens: chunk_tokens, ..SchedulerOpts::default() };
    let mut sched = Scheduler::new(Engine::synthetic(&ModelConfig::TINY, 0x17A), opts);
    for i in 0..4 {
        let mut r = GenRequest::greedy(i, &format!("steady decode stream {i}"), 96);
        r.stop_at_eos = false;
        sched.submit(r);
    }
    // let every stream reach steady decode before the interference arrives
    for _ in 0..12 {
        sched.step().expect("warmup step");
    }
    let filler = "the immutable tensor architecture keeps all dynamic state on the host. ";
    let long_prompt: String = filler.repeat(long_prompt_tokens / filler.len() + 1);
    let mut long = GenRequest::greedy(99, &long_prompt[..long_prompt_tokens], 8);
    long.stop_at_eos = false;
    sched.submit(long);
    let t0 = Instant::now();
    let results = sched.run_to_completion().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    let label = if chunk_tokens == 0 {
        "run-to-completion".to_string()
    } else {
        format!("chunk {chunk_tokens:>4}")
    };
    println!(
        "bench e2e/mixed-prefill  {label:<17} itl_step p50 {:>7.2} ms  p99 {:>8.2} ms  \
         max {:>8.2} ms  ({} mixed waves, {} chunks, {:.2}s)",
        m.itl_step.percentile(50.0) * 1e3,
        m.itl_step.percentile(99.0) * 1e3,
        m.itl_step.percentile(100.0) * 1e3,
        m.mixed_waves,
        m.prefill_chunks,
        wall,
    );
    let mut j = Json::default();
    j.num("prefill_chunk_tokens", chunk_tokens);
    j.num("long_prompt_tokens", long_prompt_tokens);
    j.num("requests", results.len());
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.num("mixed_waves", m.mixed_waves);
    j.num("prefill_chunks", m.prefill_chunks);
    j.float("itl_step_p50_ms", m.itl_step.percentile(50.0) * 1e3);
    j.float("itl_step_p99_ms", m.itl_step.percentile(99.0) * 1e3);
    j.float("itl_step_max_ms", m.itl_step.percentile(100.0) * 1e3);
    put_observability(&mut j, &m);
    j.encode()
}

/// Pipeline-parallel sweep: the same decode-heavy workload on a K-stage
/// pipelined cartridge group (K = 1 is the unsharded baseline — transcripts
/// are byte-identical for every K by construction, so the interesting
/// numbers are stage occupancy, the modeled link-transfer share, and the
/// activation bytes crossing the inter-stage links). Returns the JSON
/// record.
fn bench_pipeline(stages: usize, n_requests: usize, max_tokens: usize) -> String {
    // 4 layers so K=4 puts one layer per stage while K=2 gets two each
    let cfg = ModelConfig {
        name: "tiny-4l",
        d_model: 64,
        n_layers: 4,
        d_ffn: 192,
        n_heads: 4,
        vocab: 258,
        w_bits: 4,
        a_bits: 8,
    };
    let engine = PipelineEngine::new(stages).synthetic(&cfg, 0x17A);
    let mut sched = Scheduler::new(engine, SchedulerOpts::default());
    for i in 0..n_requests {
        let mut r =
            GenRequest::greedy(i as u64, &format!("pipelined decode stream {i}"), max_tokens);
        r.stop_at_eos = false;
        sched.submit(r);
    }
    let t0 = Instant::now();
    let results = sched.run_to_completion().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    println!(
        "bench e2e/pipeline  K={stages}  {tokens:>5} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (occupancy {:.2}, {} hops, {:.2} MB over link, link share {:.1}%)",
        tokens as f64 / wall,
        m.stage_occupancy(),
        m.link_hops,
        m.link_bytes as f64 / 1e6,
        m.link_share() * 100.0,
    );
    let mut j = Json::default();
    j.num("stages", stages);
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.float("tok_per_s", tokens as f64 / wall);
    j.float("stage_occupancy", m.stage_occupancy());
    j.num("link_hops", m.link_hops);
    j.num("link_bytes", m.link_bytes);
    j.float("link_time_s", m.link_time_s);
    j.float("link_share", m.link_share());
    put_observability(&mut j, &m);
    j.encode()
}

/// Speculative-decoding sweep: the same decode-heavy workload at draft
/// depth k (0 = vanilla), over a small 1×32 draft model paired with the
/// TINY target. Reports acceptance rate, rollbacks, and decoded tok/s —
/// on the CPU sim the draft costs real host time, so the interesting
/// numbers are acceptance and wave counts; on a physical draft cartridge
/// the proposals are concurrent. Returns the JSON record.
fn bench_spec_decode(depth: usize, n_requests: usize, max_tokens: usize) -> String {
    let draft_cfg = ModelConfig {
        name: "draft-tiny",
        d_model: 32,
        n_layers: 1,
        d_ffn: 96,
        n_heads: 2,
        vocab: 258,
        w_bits: 4,
        a_bits: 8,
    };
    let opts = SchedulerOpts {
        spec: SpecOpts { depth, adaptive: true },
        ..SchedulerOpts::default()
    };
    let target = Engine::synthetic(&ModelConfig::TINY, 0x17A);
    let engines = if depth == 0 {
        CartridgeEngines::from(target)
    } else {
        CartridgeEngines::with_draft(target, Engine::synthetic(&draft_cfg, 0xD))
    };
    let mut sched = Scheduler::with_engines(engines, opts);
    for i in 0..n_requests {
        let mut r = GenRequest::greedy(
            i as u64,
            &format!("speculative decode stream {i}"),
            max_tokens,
        );
        r.stop_at_eos = false;
        sched.submit(r);
    }
    let t0 = Instant::now();
    let results = sched.run_to_completion().expect("run");
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    println!(
        "bench e2e/spec-decode  k={depth}  {tokens:>5} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (proposed {}, accepted {}, rollbacks {}, acceptance {:.0}%)",
        tokens as f64 / wall,
        m.spec_proposed,
        m.spec_accepted,
        m.spec_rollbacks,
        m.spec_acceptance() * 100.0,
    );
    let mut j = Json::default();
    j.num("depth", depth);
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.float("tok_per_s", tokens as f64 / wall);
    j.num("spec_proposed", m.spec_proposed);
    j.num("spec_accepted", m.spec_accepted);
    j.num("spec_rollbacks", m.spec_rollbacks);
    j.float("acceptance_rate", m.spec_acceptance());
    j.float("itl_step_p50_ms", m.itl_step.percentile(50.0) * 1e3);
    j.float("itl_step_p99_ms", m.itl_step.percentile(99.0) * 1e3);
    put_observability(&mut j, &m);
    j.encode()
}

/// p99 by sort (mutates its input); 0 on empty.
fn p99(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[((xs.len() - 1) as f64 * 0.99).round() as usize]
}

/// Serial calibration for the overload sweep: one request in flight at a
/// time through a default front door. Returns (service rate in req/s, p99
/// per-request inter-token latency) — the reference the overload multiples
/// and the ITL SLO target are defined against.
fn calibrate_uncontended() -> (f64, f64) {
    let front = FrontDoor::start(
        2,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
        FrontDoorOpts::default(),
    )
    .expect("calibration front door");
    let timed = workload::generate(&WorkloadSpec {
        arrivals: Arrivals::Closed,
        ..WorkloadSpec::e2e_default(16)
    });
    let n = timed.len();
    let mut itls = Vec::new();
    let t0 = Instant::now();
    for tr in timed {
        let r = front.submit(tr.request).expect("uncontended submit").wait().expect("completes");
        if r.tokens.len() > 1 {
            itls.push(r.itl_s);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    front.shutdown().expect("calibration shutdown");
    (n as f64 / wall.max(1e-9), p99(&mut itls))
}

/// Overload sweep: a bursty arrival storm at `overload`× the calibrated
/// service rate through the streaming front door. `admission = false` is
/// the baseline (admit everything, no SLO); `admission = true` configures
/// the ITL target (capping concurrent decodes per cartridge), a queue-wait
/// budget (shedding with a typed `Overloaded` error), and the adaptive
/// prefill controller. Reports the p99 inter-token latency of admitted
/// requests, the shed rate against offered load, and goodput. Returns the
/// JSON record.
fn bench_overload(
    overload: f64,
    service_rate: f64,
    target_itl_s: f64,
    admission: bool,
) -> String {
    let door = if admission {
        FrontDoorOpts {
            target_itl_s: Some(target_itl_s),
            queue_budget_s: Some(0.25),
            adaptive_prefill: true,
        }
    } else {
        FrontDoorOpts::default()
    };
    let front = FrontDoor::start(
        2,
        |_id| Ok(Engine::synthetic(&ModelConfig::TINY, 0x17A)),
        SchedulerOpts::default(),
        door,
    )
    .expect("front door start");
    let spec = WorkloadSpec {
        arrivals: Arrivals::Bursty {
            base: service_rate * overload * 0.1,
            peak: service_rate * overload,
            period_s: 0.5,
            duty: 0.5,
        },
        heavy_tail_alpha: Some(1.5),
        ..WorkloadSpec::e2e_default(96)
    };
    let offered = spec.n_requests;
    let t0 = Instant::now();
    let mut streams = Vec::new();
    let mut shed = 0usize;
    for tr in workload::generate(&spec) {
        let wait = tr.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        match front.submit(tr.request) {
            Ok(s) => streams.push(s),
            Err(SubmitError::Overloaded { .. }) => shed += 1,
            Err(SubmitError::Closed) => panic!("fleet closed mid-bench"),
        }
    }
    let results: Vec<_> =
        streams.into_iter().map(|s| s.wait().expect("admitted request completes")).collect();
    let wall = t0.elapsed().as_secs_f64();
    let m = front.shutdown().expect("fleet shutdown");
    let mut itls: Vec<f64> =
        results.iter().filter(|r| r.tokens.len() > 1).map(|r| r.itl_s).collect();
    let p99_itl = p99(&mut itls);
    let shed_rate = shed as f64 / offered as f64;
    let goodput = results.len() as f64 / wall.max(1e-9);
    let label = if admission { "admission" } else { "baseline " };
    println!(
        "bench e2e/overload x{overload:<5.0} {label} {offered:>3} offered, {:>3} admitted, \
         {shed:>3} shed ({:>4.0}%)  p99 itl {:>7.2} ms (target {:.2} ms)  \
         goodput {goodput:>6.1} req/s",
        results.len(),
        shed_rate * 100.0,
        p99_itl * 1e3,
        target_itl_s * 1e3,
    );
    let mut j = Json::default();
    j.float("overload_x", overload);
    j.str("mode", if admission { "admission" } else { "baseline" });
    j.num("offered", offered);
    j.num("admitted", results.len());
    j.num("shed", shed);
    j.float("shed_rate", shed_rate);
    j.float("p99_itl_ms", p99_itl * 1e3);
    j.float("target_itl_ms", target_itl_s * 1e3);
    j.float("goodput_req_per_s", goodput);
    j.float("wall_s", wall);
    j.num("fleet_shed_requests", m.shed_requests);
    j.num("fleet_cancelled_requests", m.cancelled_requests);
    put_observability(&mut j, &m.aggregate());
    j.encode()
}

/// KV memory-tier sweep (`docs/kv-memory-tiers.md`): the same decode
/// workload under each cold-page encoding (fp32/int8/int4), sampling the
/// peak resident KV bytes every step — the number that decides how many
/// concurrent sessions a fixed KV arena sustains. `budget_bytes > 0` legs
/// additionally enable the disk spill tier under that (deliberately tight)
/// budget, reporting the spill churn it takes to hold the workload.
/// Streams are pinned elsewhere (`rust/tests/kv_quant_sim.rs` /
/// `kv_spill_sim.rs`); here the interesting numbers are bytes and tok/s.
/// Returns the JSON record.
fn bench_kv_capacity(tag: KvQuantTag, budget_bytes: usize) -> String {
    let n_requests = 8usize;
    let max_tokens = 32usize;
    let spill = budget_bytes > 0;
    let opts = SchedulerOpts {
        kv_mem: KvMemOpts { quant: tag, hot_window: 16, budget_bytes, spill },
        ..SchedulerOpts::default()
    };
    let mut sched = Scheduler::new(Engine::synthetic(&ModelConfig::TINY, 0x17A), opts);
    for i in 0..n_requests {
        let mut r = GenRequest::greedy(i as u64, &format!("kv capacity session {i}"), max_tokens);
        r.stop_at_eos = false;
        sched.submit(r);
    }
    let t0 = Instant::now();
    let mut peak_resident = 0usize;
    let mut results = Vec::new();
    while sched.pending() > 0 {
        results.extend(sched.step().expect("step"));
        peak_resident = peak_resident.max(sched.engine().kv_resident_bytes());
    }
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let m = sched.metrics();
    let bytes_per_session = peak_resident / n_requests;
    // sessions a fixed 4 MiB KV arena sustains at this peak footprint
    // (only meaningful for unbudgeted legs — a budget caps the peak)
    const ARENA_BYTES: usize = 4 << 20;
    let sessions = if bytes_per_session == 0 { 0 } else { ARENA_BYTES / bytes_per_session };
    // steady-state checkpoint cost at the final context length: a full
    // snapshot vs one 8-token delta (24-byte envelope + appended rows);
    // wire_bytes_for is the wire format's single source of truth
    let ctx = results.first().map(|r| r.prompt_tokens + r.tokens.len() - 1).unwrap_or(0);
    let cfg = &ModelConfig::TINY;
    let full_ckpt = KvSnapshot::wire_bytes_for(cfg.n_layers, cfg.d_model, ctx);
    let delta_ckpt = 24 + KvSnapshot::wire_bytes_for(cfg.n_layers, cfg.d_model, 8);
    let label = match tag {
        KvQuantTag::Fp32 => "fp32",
        KvQuantTag::Int8Block => "int8",
        KvQuantTag::Int4Block => "int4",
    };
    println!(
        "bench e2e/kv-capacity {label} budget {budget_bytes:>6}  {tokens:>4} tokens in \
         {wall:>5.2}s = {:>7.1} tok/s  (peak {:>6} B resident, {:>5} B/session, \
         {sessions:>4} sessions/4MiB, {} pages quantized, {} spills)",
        tokens as f64 / wall,
        peak_resident,
        bytes_per_session,
        m.kv_pages_quantized,
        m.kv_spills,
    );
    let mut j = Json::default();
    j.str("quant", label);
    j.num("budget_bytes", budget_bytes);
    j.str("spill", if spill { "on" } else { "off" });
    j.num("requests", n_requests);
    j.num("tokens", tokens);
    j.float("wall_s", wall);
    j.float("tok_per_s", tokens as f64 / wall);
    j.num("peak_resident_bytes", peak_resident);
    j.num("bytes_per_session", bytes_per_session);
    j.num("sessions_at_4mib", sessions);
    j.num("kv_pages_quantized", m.kv_pages_quantized);
    j.num("kv_spills", m.kv_spills);
    j.num("kv_unspills", m.kv_unspills);
    j.num("kv_spill_bytes", m.kv_spill_bytes);
    j.num("full_checkpoint_bytes", full_ckpt);
    j.num("delta_checkpoint_bytes", delta_ckpt);
    // actually-emitted periodic checkpoint bytes, full vs delta
    j.num("ckpt_full_bytes", m.ckpt_full_bytes);
    j.num("ckpt_delta_bytes", m.ckpt_delta_bytes);
    put_observability(&mut j, &m);
    j.encode()
}

fn bench_config(name: &str, n_requests: usize, max_tokens: usize) -> Option<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    if !dir.join("MANIFEST.txt").exists() {
        eprintln!("skip {name}: artifacts missing");
        return None;
    }
    let (m, s) = load_artifacts(&dir).ok()?;
    let n_heads = m.n_heads;
    let sim = SimDevice::load(&m, &s).ok()?;
    let emb = EmbeddingTable::new(sim.weights().emb.clone());
    let t_compile = Instant::now();
    let dev = match PjrtDevice::load(m, &s, "fused") {
        Ok(d) => d,
        Err(e) => {
            eprintln!("skip {name}: {e:#}");
            return None;
        }
    };
    let compile_s = t_compile.elapsed().as_secs_f64();

    let engine = Engine::new(Box::new(dev), emb, n_heads);
    let mut sched = Scheduler::new(engine, SchedulerOpts::default());
    for i in 0..n_requests {
        sched.submit(GenRequest {
            id: i as u64,
            prompt: "end to end throughput".into(),
            max_new_tokens: max_tokens,
            sampling: ita::host::sampling::SamplingParams::greedy(),
            stop_at_eos: false,
        });
    }
    let t0 = Instant::now();
    let results = sched.run_to_completion().ok()?;
    let wall = t0.elapsed().as_secs_f64();
    let m = sched.metrics();
    let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "bench e2e/{name:<22} {:>6} tokens in {wall:>6.2}s = {:>7.1} tok/s  \
         (compile {compile_s:.1}s, batch_waste {:.1}%, {:.1} MB interface)",
        tokens,
        tokens as f64 / wall,
        m.batch_waste * 100.0,
        m.interface_bytes as f64 / 1e6,
    );
    Some(())
}

fn main() {
    // cartridge-count sweep: the stateless device makes scale-out a pure
    // host-coordination exercise — aggregate throughput should grow until
    // host attention threads saturate the machine
    let mut fleet_sweep = Vec::new();
    for cartridges in [1usize, 2, 4] {
        fleet_sweep.push(bench_fleet(cartridges, 32, 16));
    }
    // shared-prefix workload: 32 requests behind one long system prompt
    let shared_prefix = bench_shared_prefix(32, 8);
    // skewed workload: live KV migration rebalances mid-decode
    let migration = bench_migration(16, 48, 4);
    // long-prefill interference: run-to-completion vs chunked prefill —
    // the decode inter-token gap is the number continuous batching fixes
    let mixed_sweep = vec![
        bench_mixed_prefill_decode(0, 2048),
        bench_mixed_prefill_decode(64, 2048),
        bench_mixed_prefill_decode(256, 2048),
    ];
    // speculative decoding: draft depth sweep (0 = vanilla baseline);
    // acceptance rate + rollbacks land in the perf record
    let spec_sweep: Vec<String> =
        [0usize, 2, 4, 8].iter().map(|&k| bench_spec_decode(k, 8, 48)).collect();
    // pipeline-parallel sharding: stage-count sweep on a 4-layer model —
    // occupancy and modeled link share quantify the cost of splitting one
    // logical cartridge across K dies
    let pipeline_sweep: Vec<String> =
        [1usize, 2, 4].iter().map(|&k| bench_pipeline(k, 8, 32)).collect();
    // request-lifecycle tracing must be free when off: same workload with
    // the recorder disabled vs live, tok/s delta in the record
    let tracing_overhead = bench_tracing_overhead(8, 64);
    // the live observability plane at its three postures (off, tail-sampled
    // production, full post-mortem) + a per-tenant overload storm whose
    // labeled series split the damage per (tenant, class) lane
    let live_telemetry = bench_live_telemetry(8, 64);
    // KV memory tiers: peak per-session footprint under each cold-page
    // encoding (the session-capacity win of int8/int4), then fp32 and int8
    // under a deliberately tight 16 KiB budget with the disk spill tier
    // holding the same workload together
    let mut kv_capacity_sweep = Vec::new();
    for tag in [KvQuantTag::Fp32, KvQuantTag::Int8Block, KvQuantTag::Int4Block] {
        kv_capacity_sweep.push(bench_kv_capacity(tag, 0));
    }
    kv_capacity_sweep.push(bench_kv_capacity(KvQuantTag::Fp32, 16 << 10));
    kv_capacity_sweep.push(bench_kv_capacity(KvQuantTag::Int8Block, 16 << 10));
    // overload storms through the streaming front door: baseline (admit
    // everything) vs admission-controlled, at 10× and 100× the serially
    // calibrated service rate
    let (service_rate, itl_uncontended) = calibrate_uncontended();
    let target_itl_s = (itl_uncontended * 3.0).max(1e-4);
    println!(
        "bench e2e/overload calibrated: {service_rate:.1} req/s serial, \
         p99 itl {:.2} ms -> SLO target {:.2} ms",
        itl_uncontended * 1e3,
        target_itl_s * 1e3
    );
    let mut overload_sweep = Vec::new();
    for x in [10.0f64, 100.0] {
        overload_sweep.push(bench_overload(x, service_rate, target_itl_s, false));
        overload_sweep.push(bench_overload(x, service_rate, target_itl_s, true));
    }
    bench_config("tiny", 16, 32);
    // saturate the largest compiled bucket: at the DRAM-streaming roofline
    // every extra row in a weight sweep is almost free (§Perf iteration 5)
    bench_config("demo-100m", 16, 16);

    // machine-readable perf record (CI uploads it as a workflow artifact)
    let mut root = Json::default();
    root.str("bench", "e2e_throughput");
    // v2: added the mixed_prefill_decode sweep (chunked-prefill ITL)
    // v3: added the spec_decode sweep (draft depth, acceptance, rollbacks)
    // v4: added the pipeline sweep (stage count, occupancy, link share)
    // v5: every sweep carries joules_per_token + queue_wait p50/p99; added
    //     the tracing_overhead record (traced vs untraced tok/s delta)
    // v6: added the overload sweep (bursty storms at 10×/100× the measured
    //     service rate through the streaming front door; p99 admitted ITL,
    //     shed rate, and goodput, baseline vs admission-controlled)
    // v7: added the kv_capacity sweep (peak resident KV bytes per session
    //     under fp32/int8/int4 cold pages, sessions-per-arena, spill-tier
    //     churn under a tight budget, full vs delta checkpoint bytes)
    // v8: added the live_telemetry record (tok/s at the off / tail-sampled
    //     / full-tracing observability postures with the tail-sampled
    //     overhead pin, plus the per-tenant overload storm: per-lane shed
    //     rate, admitted-ITL and queue-wait p99s, alert state at shutdown)
    root.num("schema_version", 8);
    root.put("fleet_sweep", json_array(&fleet_sweep));
    root.put("shared_prefix", shared_prefix);
    root.put("migration", migration);
    root.put("mixed_prefill_decode", json_array(&mixed_sweep));
    root.put("spec_decode", json_array(&spec_sweep));
    root.put("pipeline", json_array(&pipeline_sweep));
    root.put("tracing_overhead", tracing_overhead);
    root.put("live_telemetry", live_telemetry);
    root.put("kv_capacity", json_array(&kv_capacity_sweep));
    root.put("overload", json_array(&overload_sweep));
    let path = std::env::var("ITA_BENCH_JSON").unwrap_or_else(|_| "BENCH_e2e.json".into());
    match std::fs::write(&path, root.encode() + "\n") {
        Ok(()) => println!("bench e2e: wrote perf record to {path}"),
        Err(e) => eprintln!("bench e2e: could not write {path}: {e}"),
    }
}
