//! Bench + regeneration of Fig 3 (economic barrier to model extraction).
//! `cargo bench --bench fig3_extraction_cost`

use ita::security::{barrier_ratio, extraction_floor_usd, Target};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    b.bench("fig3/extraction_floors", || {
        (
            extraction_floor_usd(Target::SoftwareReadable),
            extraction_floor_usd(Target::PhysicalLogic),
        )
    });

    ita::report::fig3_report().print();
    println!("\nbarrier ratio: {:.0}x (paper: 25x)", barrier_ratio());
}
