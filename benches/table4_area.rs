//! Bench + regeneration of Table IV (die area / chiplets / cost).
//! `cargo bench --bench table4_area`

use ita::area::{estimate, Routing};
use ita::config::{ModelConfig, TechParams};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let tech = TechParams::paper_28nm();

    b.bench("table4/estimate_all_models", || {
        ita::config::ALL_CONFIGS
            .iter()
            .map(|c| estimate(c, &tech, Routing::Optimistic).final_mm2)
            .sum::<f64>()
    });

    ita::report::table4_report().print();

    // the paper's own arithmetic chain for TinyLlama, step by step
    let e = estimate(&ModelConfig::TINYLLAMA_1_1B, &tech, Routing::Optimistic);
    println!(
        "\nTinyLlama chain: raw {:.0} mm² (paper 528) → routed+control {:.0} (paper 850) → \
         final {:.0} (paper 520)",
        e.raw_mm2, e.routed_mm2, e.final_mm2
    );
}
