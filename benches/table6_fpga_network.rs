//! Bench + regeneration of Table VI (full 64→128→64 network on Zynq-7020).
//! `cargo bench --bench table6_fpga_network`

use ita::synth::fpga::{baseline_network, hardwired_network, proto_network_weights, FpgaCosts};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::default();
    let costs = FpgaCosts::default();
    let weights = proto_network_weights(0x17A);

    b.bench("table6/map_hardwired_16k_macs", || {
        hardwired_network(&weights, 8, &costs).luts
    });
    b.bench("table6/map_baseline", || baseline_network(8, 4, &costs).luts);

    ita::report::table6_report().print();
}
