//! Bench + regeneration of Table II (energy per MAC) and the Section VI-B1
//! system-power analysis. `cargo bench --bench table2_energy`

use ita::config::ModelConfig;
use ita::energy::{device_power_w, dram_floor_j_per_token, system_power, EnergyParams};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let e = EnergyParams::default();

    b.bench("table2/full_stack_eval", || {
        (e.gpu_fp16().total_pj(), e.gpu_int8().total_pj(), e.ita().total_pj())
    });
    b.bench("table2/system_power_7b", || {
        system_power(&ModelConfig::LLAMA2_7B, &e, 20.0).total_w
    });

    ita::report::table2_report().print();

    // Eq. 2: the DRAM floor the whole paper is built on
    println!(
        "\nEq. 2 check: 14 GB FP16 7B model = {:.2} J/token DRAM floor (paper 2.24 J)",
        dram_floor_j_per_token(14_000_000_000, 8, 20.0)
    );
    println!(
        "device power @20 tok/s: {:.2} W (paper 1.13 W)",
        device_power_w(&ModelConfig::LLAMA2_7B, &e, 20.0)
    );
}
