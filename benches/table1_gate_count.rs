//! Bench + regeneration of Table I (gate count per MAC).
//! `cargo bench --bench table1_gate_count`

use ita::synth::gates::CellCosts;
use ita::synth::mac::{sample_int4_weights, table1};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::default();
    let weights = sample_int4_weights(65_536, 0x17A);
    let costs = CellCosts::asic_28nm();

    b.bench("table1/synthesize_64k_macs", || table1(&costs, &weights));
    b.bench("table1/csd_encode_64k", || {
        weights.iter().map(|&w| ita::quant::csd::csd_nonzero(w as i64)).sum::<usize>()
    });

    ita::report::table1_report().print();
}
