//! Bench + regeneration of Table VIII (edge-NPU comparison).
//! `cargo bench --bench table8_edge_npu`

use ita::config::ModelConfig;
use ita::interface::npu::{energy_per_token_j, ita_row};
use ita::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::quick();
    b.bench("table8/ita_row", || ita_row(&ModelConfig::LLAMA2_7B, 165.0).power_w);

    ita::report::table8_report().print();

    let ita = ita_row(&ModelConfig::LLAMA2_7B, 165.0);
    println!(
        "\nenergy per token at 20 tok/s: ITA {:.1} mJ vs Hexagon ≈{:.1} mJ",
        energy_per_token_j(ita.power_w, 20.0) * 1e3,
        energy_per_token_j(1.5, 20.0) * 1e3
    );
}
