"""L1 Pallas kernels vs the pure-jnp/numpy oracle — the CORE correctness
signal for the device compute path (hypothesis sweeps shapes/seeds)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize
from compile.kernels import hardwired
from compile.kernels.ref import recompose, ref_int_matmul


def _random_case(seed, b, k, n, w_bits=4):
    rng = np.random.default_rng(seed)
    x_q = rng.integers(-127, 128, size=(b, k), dtype=np.int8)
    w = rng.standard_normal((k, n)).astype(np.float32)
    w_q, scale = quantize.quantize_weights(w, bits=w_bits)
    planes = quantize.csd_planes(w_q, w_bits)
    return x_q, w_q, planes, scale


@given(st.integers(0, 2**32 - 1), st.integers(1, 8),
       st.sampled_from([3, 8, 16, 64, 100]), st.sampled_from([1, 4, 16, 96]))
@settings(max_examples=40, deadline=None)
def test_csd_matmul_exact(seed, b, k, n):
    x_q, w_q, planes, _ = _random_case(seed, b, k, n)
    got = np.asarray(hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes)))
    np.testing.assert_array_equal(got, ref_int_matmul(x_q, w_q))


@given(st.integers(0, 2**32 - 1), st.integers(1, 4), st.sampled_from([2, 3, 5, 6]))
@settings(max_examples=20, deadline=None)
def test_csd_matmul_exact_other_widths(seed, b, w_bits):
    """Kernel is width-generic: plane count follows w_bits."""
    x_q, w_q, planes, _ = _random_case(seed, b, 24, 8, w_bits=w_bits)
    got = np.asarray(hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes)))
    np.testing.assert_array_equal(got, ref_int_matmul(x_q, w_q))


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_fused_matmul_bitexact_vs_csd(seed):
    """The f32 fast path equals the int shift-add path bit-for-bit while
    |acc| < 2^24 (DESIGN.md numbers policy)."""
    x_q, w_q, planes, _ = _random_case(seed, 4, 128, 32)
    csd = np.asarray(hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes)))
    fused = np.asarray(hardwired.fused_matmul(
        jnp.asarray(x_q, jnp.float32), jnp.asarray(w_q, jnp.float32)))
    np.testing.assert_array_equal(fused.astype(np.int32), csd)
    assert np.abs(csd).max() < 2 ** 24


@pytest.mark.parametrize("block_n", [4, 8, 16])
def test_csd_matmul_tiled_equals_untiled(block_n):
    x_q, w_q, planes, _ = _random_case(0, 2, 32, 48)
    full = hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes))
    tiled = hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes), block_n=block_n)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(full))


@pytest.mark.parametrize("block_n", [8, 24])
def test_fused_matmul_tiled_equals_untiled(block_n):
    rng = np.random.default_rng(1)
    x = rng.integers(-127, 128, size=(3, 16)).astype(np.float32)
    w = rng.integers(-7, 8, size=(16, 48)).astype(np.float32)
    full = hardwired.fused_matmul(jnp.asarray(x), jnp.asarray(w))
    tiled = hardwired.fused_matmul(jnp.asarray(x), jnp.asarray(w), block_n=block_n)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(full))


def test_zero_planes_give_zero_output():
    planes = np.zeros((4, 16, 8), np.int8)
    x_q = np.full((2, 16), 127, np.int8)
    got = np.asarray(hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes)))
    assert (got == 0).all()


def test_extreme_values_no_overflow():
    """Worst-case magnitudes stay within int32 and within the f32-exact bound."""
    k = 2048  # largest contraction dim we build (demo-100m FFN down-proj)
    x_q = np.full((1, k), 127, np.int8)
    w_q = np.full((k, 4), 7, np.int8)
    planes = quantize.csd_planes(w_q, 4)
    got = np.asarray(hardwired.csd_matmul(jnp.asarray(x_q), jnp.asarray(planes)))
    expect = 127 * 7 * k
    assert (got == expect).all() and expect < 2 ** 24


def test_vmem_footprint_model():
    full = hardwired.vmem_footprint_bytes(8, 768, 2304, variant="csd")
    tiled = hardwired.vmem_footprint_bytes(8, 768, 2304, block_n=128, variant="csd")
    assert tiled < full
    # the demo-100m qkv tile at block_n=128 must fit a 16 MB VMEM budget
    assert tiled < 16 * 2 ** 20


def test_mxu_utilization_estimate_bounds():
    u = hardwired.mxu_utilization_estimate(1, 768, 2304)
    assert 0.0 < u <= 1.0
    assert hardwired.mxu_utilization_estimate(128, 768, 2304) > u
