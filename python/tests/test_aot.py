"""AOT build pipeline: manifest structure, blob integrity, determinism."""

import os

import numpy as np
import pytest

from compile import aot
from compile.configs import CONFIGS


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build_config(CONFIGS["tiny"], str(out), buckets=[1, 2], variants=["fused", "csd"],
                     mode="baked")
    return os.path.join(str(out), "tiny")


def parse_manifest(path):
    kinds = {}
    with open(os.path.join(path, "MANIFEST.txt")) as f:
        for line in f:
            kind = line.split()[0]
            kinds.setdefault(kind, []).append(line.strip())
    return kinds


def test_manifest_has_all_sections(tiny_build):
    kinds = parse_manifest(tiny_build)
    for kind in ("manifest_version", "config", "buckets", "variants",
                 "program", "bind", "blob"):
        assert kind in kinds, f"missing {kind}"


def test_program_files_exist_and_are_hlo(tiny_build):
    kinds = parse_manifest(tiny_build)
    # tiny baked: per-layer qkv+ffn programs + logits, per bucket, per variant
    cfg = CONFIGS["tiny"]
    expect = 2 * 2 * (cfg.n_layers * 2 + 1)  # variants * buckets * blocks
    assert len(kinds["program"]) == expect
    for line in kinds["program"]:
        fields = dict(kv.split("=", 1) for kv in line.split()[1:])
        p = os.path.join(tiny_build, fields["path"])
        assert os.path.exists(p)
        text = open(p).read()
        assert "ENTRY" in text and "HloModule" in text


def test_blob_offsets_contiguous(tiny_build):
    kinds = parse_manifest(tiny_build)
    size = os.path.getsize(os.path.join(tiny_build, "weights.bin"))
    end = 0
    for line in kinds["blob"]:
        fields = dict(kv.split("=", 1) for kv in line.split()[1:])
        off, nb = int(fields["offset"]), int(fields["nbytes"])
        assert off == end
        dtype_size = {"f32": 4, "i8": 1}[fields["dtype"]]
        n_elems = int(np.prod([int(s) for s in fields["shape"].split("x")]))
        assert nb == n_elems * dtype_size
        end = off + nb
    assert end == size


def test_emb_blob_matches_tied_head(tiny_build):
    """Host embedding table == dequantized transpose of the LM head."""
    kinds = parse_manifest(tiny_build)
    blobs = {}
    for line in kinds["blob"]:
        f = dict(kv.split("=", 1) for kv in line.split()[1:])
        blobs[f["name"]] = f
    raw = open(os.path.join(tiny_build, "weights.bin"), "rb").read()

    def load(name, dtype):
        f = blobs[name]
        shape = [int(s) for s in f["shape"].split("x")]
        a = np.frombuffer(raw, dtype=dtype, count=int(np.prod(shape)),
                          offset=int(f["offset"]))
        return a.reshape(shape)

    we = load("we_f32", np.float32)       # [D, V] integer-valued
    se = load("we_scale", np.float32)     # [V]
    emb = load("emb_f32", np.float32)     # [V, D]
    np.testing.assert_allclose(emb, (we * se[None, :]).T, rtol=0, atol=0)


def test_weight_generation_deterministic():
    cfg = CONFIGS["tiny"]
    a = aot.gen_layer_weights(cfg, 0)
    b = aot.gen_layer_weights(cfg, 0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = aot.gen_layer_weights(cfg, 1)
    assert not np.array_equal(a["wqkv"], c["wqkv"])


def test_csd_and_fused_blobs_consistent(tiny_build):
    """planes blobs recompose to exactly the f32 blobs (single truth)."""
    kinds = parse_manifest(tiny_build)
    blobs = {}
    for line in kinds["blob"]:
        f = dict(kv.split("=", 1) for kv in line.split()[1:])
        blobs[f["name"]] = f
    raw = open(os.path.join(tiny_build, "weights.bin"), "rb").read()

    def load(name, dtype):
        f = blobs[name]
        shape = [int(s) for s in f["shape"].split("x")]
        return np.frombuffer(raw, dtype=dtype, count=int(np.prod(shape)),
                             offset=int(f["offset"])).reshape(shape)

    planes = load("wqkv_planes_l0", np.int8)
    f32 = load("wqkv_f32_l0", np.float32)
    rec = sum(planes[p].astype(np.int32) << p for p in range(planes.shape[0]))
    np.testing.assert_array_equal(rec.astype(np.float32), f32)
