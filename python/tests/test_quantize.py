"""Properties of Logic-Aware Quantization: CSD encoding, pruning, scales."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 8])
def test_csd_recomposes_every_value_in_range(bits):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    v = np.arange(lo, hi + 1, dtype=np.int64)
    digits = quantize.csd_digits(v, bits)
    recomposed = sum(digits[p].astype(np.int64) << p for p in range(bits))
    np.testing.assert_array_equal(recomposed, v)


@pytest.mark.parametrize("bits", [3, 4, 6])
def test_csd_digits_are_signed_binary(bits):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    digits = quantize.csd_digits(np.arange(lo, hi + 1), bits)
    assert set(np.unique(digits)) <= {-1, 0, 1}


@pytest.mark.parametrize("bits", [3, 4, 6, 8])
def test_csd_non_adjacent_form(bits):
    """NAF property: no two adjacent non-zero digits (paper Section IV-C1)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    digits = quantize.csd_digits(np.arange(lo, hi + 1), bits)  # [bits, n]
    nz = digits != 0
    adjacent = nz[:-1] & nz[1:]
    assert not adjacent.any()


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_csd_digit_count_bound(bits):
    """NAF has at most ceil(bits/2)+ nonzeros; for INT4 the max is 2."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    nnz = quantize.csd_nonzero_digits(np.arange(lo, hi + 1), bits)
    assert nnz.max() <= (bits + 1) // 2


def test_csd_out_of_range_raises():
    with pytest.raises(ValueError):
        quantize.csd_digits(np.array([11]), 4)  # NAF of 11 needs position 4


def test_csd_matches_paper_example_seven():
    """Paper: decimal 7 = CSD 100-1 (one subtraction: 8 - 1)."""
    d = quantize.csd_digits(np.array([7]), 4)[:, 0]
    assert list(d) == [-1, 0, 0, 1]  # position 0 digit -1, position 3 digit +1
    assert (d != 0).sum() == 2


@given(st.integers(0, 2**32 - 1), st.integers(2, 64), st.integers(2, 6))
@settings(max_examples=100, deadline=None)
def test_quantize_weights_range_and_scale(seed, k, bits):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, 3)).astype(np.float32)
    w_q, scale = quantize.quantize_weights(w, bits=bits, prune=False)
    q = quantize.qmax(bits)
    assert w_q.min() >= -q and w_q.max() <= q
    assert scale.shape == (3,)
    # max-magnitude weight per column must hit the rail (symmetric max scaling)
    assert (np.abs(w_q).max(axis=0) == q).all()


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_prune_threshold(seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 8)).astype(np.float32) * 0.05
    w_q, scale = quantize.quantize_weights(w, bits=4, prune=True)
    dq = w_q.astype(np.float32) * scale[None, :]
    nz = dq[w_q != 0]
    assert (np.abs(nz) >= quantize.PRUNE_THRESHOLD).all()


def test_pruned_fraction_band_for_gaussian_weights():
    """Paper Section IV-C3 claims 15-25% of weights prune away for typical
    quantized models; our synthetic gaussians land in a similar band."""
    rng = np.random.default_rng(7)
    w = (rng.standard_normal((768, 768)).astype(np.float32) / np.sqrt(768))
    w_q, _ = quantize.quantize_weights(w, bits=4)
    frac = quantize.pruned_fraction(w_q)
    assert 0.03 < frac < 0.40


@given(st.integers(0, 2**32 - 1), st.integers(2, 32), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_planes_recompose_to_quantized_weights(seed, k, n):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    w_q, _ = quantize.quantize_weights(w, bits=4)
    planes = quantize.csd_planes(w_q, 4)
    from compile.kernels.ref import recompose
    np.testing.assert_array_equal(recompose(planes), w_q.astype(np.int32))
