"""L2 device blocks vs pure-jnp references; csd/fused variant agreement."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model, quantize
from compile.configs import CONFIGS
from compile.kernels import ref


def make_params(seed, d, n_out, w_bits=4):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((d, n_out)).astype(np.float32) / np.sqrt(d))
    w_q, scale = quantize.quantize_weights(w, bits=w_bits)
    planes = quantize.csd_planes(w_q, w_bits)
    return w_q, planes, scale


def hidden(seed, b, d):
    return jnp.asarray(np.random.default_rng(seed).standard_normal((b, d)), jnp.float32)


@pytest.mark.parametrize("variant", ["csd", "fused"])
@pytest.mark.parametrize("b,d", [(1, 32), (4, 64)])
def test_qkv_block_matches_ref(variant, b, d):
    w_q, planes, scale = make_params(0, d, 3 * d)
    g1 = jnp.ones(d)
    h = hidden(1, b, d)
    w = jnp.asarray(planes) if variant == "csd" else jnp.asarray(w_q, jnp.float32)
    q, k, v = model.qkv_block(h, g1, w, jnp.asarray(scale), d_model=d, variant=variant)
    rq, rk, rv = ref.qkv_block_ref(h, g1, jnp.asarray(w_q), jnp.asarray(scale), d)
    np.testing.assert_allclose(np.asarray(q), np.asarray(rq), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(k), np.asarray(rk), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("variant", ["csd", "fused"])
def test_ffn_block_matches_ref(variant):
    b, d, f = 2, 48, 96
    wo_q, wo_p, wo_s = make_params(1, d, d)
    w1_q, w1_p, w1_s = make_params(2, d, f)
    w3_q, w3_p, w3_s = make_params(3, d, f)
    w2_q, w2_p, w2_s = make_params(4, f, d)
    g2 = jnp.ones(d)
    h, attn = hidden(5, b, d), hidden(6, b, d)
    pick = (lambda q, p: jnp.asarray(p)) if variant == "csd" else (
        lambda q, p: jnp.asarray(q, jnp.float32))
    (out,) = model.ffn_block(
        h, attn, g2,
        pick(wo_q, wo_p), jnp.asarray(wo_s), pick(w1_q, w1_p), jnp.asarray(w1_s),
        pick(w3_q, w3_p), jnp.asarray(w3_s), pick(w2_q, w2_p), jnp.asarray(w2_s),
        variant=variant)
    want = ref.ffn_block_ref(
        h, attn, g2, jnp.asarray(wo_q), jnp.asarray(wo_s), jnp.asarray(w1_q),
        jnp.asarray(w1_s), jnp.asarray(w3_q), jnp.asarray(w3_s),
        jnp.asarray(w2_q), jnp.asarray(w2_s))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", ["csd", "fused"])
def test_logits_block_matches_ref(variant):
    b, d, v = 2, 32, 50
    we_q, we_p, we_s = make_params(7, d, v)
    gf = jnp.ones(d)
    h = hidden(8, b, d)
    w = jnp.asarray(we_p) if variant == "csd" else jnp.asarray(we_q, jnp.float32)
    (out,) = model.logits_block(h, gf, w, jnp.asarray(we_s), variant=variant)
    want = ref.logits_block_ref(h, gf, jnp.asarray(we_q), jnp.asarray(we_s))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_variants_bitexact_on_blocks():
    """csd and fused artifacts must be interchangeable at serving time."""
    b, d = 3, 64
    w_q, planes, scale = make_params(9, d, 3 * d)
    g1, h = jnp.ones(d), hidden(10, b, d)
    out_csd = model.qkv_block(h, g1, jnp.asarray(planes), jnp.asarray(scale),
                              d_model=d, variant="csd")
    out_fused = model.qkv_block(h, g1, jnp.asarray(w_q, jnp.float32),
                                jnp.asarray(scale), d_model=d, variant="fused")
    for a, b_ in zip(out_csd, out_fused):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_config_param_counts():
    """Sanity: topology accounting used across DESIGN.md and the rust side."""
    assert abs(CONFIGS["demo-100m"].params() - 99e6) < 3e6
    assert abs(CONFIGS["llama2-7b"].params() / 1e9 - 6.6) < 0.4
    assert CONFIGS["tiny"].params() < 1e6
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0


def test_rmsnorm_unit_scale():
    x = hidden(11, 2, 64) * 10.0
    y = model.rmsnorm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
