"""L2: the ITA device's compute graph (build-time JAX, calling L1 kernels).

These are the *device-side* blocks of the Split-Brain protocol (paper
Section IV-B): every weight-bearing linear operation lives here —

  * ``qkv_block``    h -> (q, k, v)            (pre-attention norm + fused QKV)
  * ``ffn_block``    (h, attn) -> h_next       (Wo + residual + SwiGLU FFN)
  * ``logits_block`` h -> logits               (final norm + tied LM head)

The host (rust) owns everything dynamic: embedding lookup, RoPE, the KV
cache, softmax attention, and sampling. Only activation vectors cross the
interface, exactly as in Fig. 1 of the paper.

Weight handling has two modes, matching aot.py:

  * ``baked``  — weights are closed-over jnp constants; they become HLO
    constants, i.e. the One-Model-One-Chip cartridge. (tiny config)
  * ``args``   — weights are runtime parameters the rust runtime uploads once
    at startup and keeps resident as PJRT buffers (the paper's Section VII-D
    hybrid/SRAM mode; used for demo-100m where baking 100M params into HLO
    text is the 520 mm^2 die, not a build step).

Two kernel variants (see kernels/hardwired.py): ``csd`` is paper-structural,
``fused`` is the bit-exact fast path.
"""

import jax
import jax.numpy as jnp

from .kernels import hardwired
from .kernels.ref import RMS_EPS


def rmsnorm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * g


def quant_act(x, a_bits: int = 8):
    q = (1 << (a_bits - 1)) - 1
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / q
    s = jnp.maximum(s, 1e-8)
    xq = jnp.clip(jnp.round(x / s), -q, q).astype(jnp.int8)
    return xq, s


def qlinear(x, weight, w_scale, variant: str):
    """Quantize activations, contract against hardwired weights, dequantize.

    Args:
      x: f32 [B, K].
      weight: csd variant -> int8 digit planes [P, K, N];
              fused variant -> integer-valued f32 [K, N] (recomposed W_q).
      w_scale: f32 [N] per-output-channel scale.
    """
    xq, xs = quant_act(x)
    if variant == "csd":
        acc = hardwired.csd_matmul(xq, weight).astype(jnp.float32)
    elif variant == "fused":
        acc = hardwired.fused_matmul(xq.astype(jnp.float32), weight)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return acc * xs * w_scale[None, :]


def silu(x):
    return x * jax.nn.sigmoid(x)


def qkv_block(h, g1, w_qkv, s_qkv, *, d_model: int, variant: str):
    """h [B, D] -> (q, k, v) each [B, D]. W_{q,k,v} fused into one matmul."""
    x = rmsnorm(h, g1)
    qkv = qlinear(x, w_qkv, s_qkv, variant)
    return (qkv[:, :d_model], qkv[:, d_model:2 * d_model], qkv[:, 2 * d_model:])


def ffn_block(h, attn, g2, w_o, s_o, w_1, s_1, w_3, s_3, w_2, s_2, *, variant: str):
    """(h, concatenated-head attention output) -> next hidden state.

    Applies the output projection Wo on-device (the paper's Eq. 8 transfer is
    the raw attention output), then residual + SwiGLU FFN (paper Eq. 5).
    """
    h = h + qlinear(attn, w_o, s_o, variant)
    x = rmsnorm(h, g2)
    a = qlinear(x, w_1, s_1, variant)
    b = qlinear(x, w_3, s_3, variant)
    return (h + qlinear(silu(a) * b, w_2, s_2, variant),)


def logits_block(h, gf, w_e, s_e, *, variant: str):
    """Final norm + tied LM head -> logits [B, V] (paper Eq. 9 transfer)."""
    x = rmsnorm(h, gf)
    return (qlinear(x, w_e, s_e, variant),)


def make_qkv_fn(d_model: int, variant: str, baked=None):
    """Returns a jit-able fn with the right signature for AOT lowering.

    baked: None for args mode, else the weight pytree (g1, w, s) to close over.
    """
    if baked is None:
        def fn(h, g1, w, s):
            return qkv_block(h, g1, w, s, d_model=d_model, variant=variant)
    else:
        g1, w, s = baked
        def fn(h):
            return qkv_block(h, g1, w, s, d_model=d_model, variant=variant)
    return fn


def make_ffn_fn(variant: str, baked=None):
    if baked is None:
        def fn(h, attn, g2, wo, so, w1, s1, w3, s3, w2, s2):
            return ffn_block(h, attn, g2, wo, so, w1, s1, w3, s3, w2, s2, variant=variant)
    else:
        g2, wo, so, w1, s1, w3, s3, w2, s2 = baked
        def fn(h, attn):
            return ffn_block(h, attn, g2, wo, so, w1, s1, w3, s3, w2, s2, variant=variant)
    return fn


def make_logits_fn(variant: str, baked=None):
    if baked is None:
        def fn(h, gf, we, se):
            return logits_block(h, gf, we, se, variant=variant)
    else:
        gf, we, se = baked
        def fn(h):
            return logits_block(h, gf, we, se, variant=variant)
    return fn
