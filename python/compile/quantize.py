"""Logic-Aware Quantization (paper Section IV-C).

Weights are quantized to INT4 per output channel, pruned against the paper's
zero-weight threshold, and decomposed into **CSD digit planes**: signed-digit
planes D_p in {-1, 0, +1}^(K x N) with

    W_q = sum_p D_p * 2^p          (p = 0 .. w_bits-1)

The digit-plane decomposition is the tensorized form of the paper's per-weight
shift-add trees: a zero digit is an adder that never gets synthesized, and the
number of non-zero digits per weight is exactly the adder count the rust-side
`synth` crate prices in gates (Table I) and LUTs (Tables VI/VII).

The same decomposition therefore feeds *numerics* (the Pallas kernel computes
`sum_p (x @ D_p) << p`) and *hardware models* — one artifact of truth.

Everything here is numpy (build-time only) and mirrored bit-for-bit by
``rust/src/quant``.
"""

import numpy as np

# Paper Section IV-C3: weights with |w| < 2^-6 are pruned and their
# multiplication units removed from the netlist entirely.
PRUNE_THRESHOLD = 2.0 ** -6


def qmax(bits: int) -> int:
    """Symmetric signed range limit, e.g. 7 for INT4."""
    return (1 << (bits - 1)) - 1


def quantize_weights(w: np.ndarray, bits: int = 4, prune: bool = True):
    """Per-output-channel symmetric quantization.

    Args:
      w: float32 [K, N] (inputs x outputs).
      bits: weight width (paper: 4).
      prune: apply the |w| < 2^-6 zero-weight threshold *after* scaling.

    Returns:
      (w_q int8 [K, N] in [-qmax, qmax], scale float32 [N])
    """
    assert w.ndim == 2
    q = qmax(bits)
    scale = np.abs(w).max(axis=0) / q
    scale = np.maximum(scale, 1e-12).astype(np.float32)
    w_q = np.clip(np.round(w / scale[None, :]), -q, q).astype(np.int8)
    if prune:
        w_q[np.abs(w_q.astype(np.float32) * scale[None, :]) < PRUNE_THRESHOLD] = 0
    return w_q, scale


def csd_digits(v: np.ndarray, bits: int) -> np.ndarray:
    """Canonical-signed-digit (non-adjacent form) decomposition.

    Args:
      v: integer array, each value in [-(2^(bits-1)), 2^(bits-1)-1].
      bits: number of digit positions (positions 0..bits-1 suffice for that
        range: 2^(b-1)-1 = +2^(b-1) - 1 uses position b-1).

    Returns:
      int8 array [bits, *v.shape] with values in {-1, 0, +1}, no two adjacent
      non-zeros (NAF property), and sum_p digits[p] * 2^p == v.
    """
    work = v.astype(np.int64).copy()
    digits = np.zeros((bits,) + v.shape, dtype=np.int8)
    for p in range(bits):
        odd = (work & 1) != 0
        # for odd work: digit = 2 - (work mod 4), i.e. +1 if work=1 mod 4,
        # -1 if work=3 mod 4 -> guarantees the next bit is even (NAF)
        d = np.where(odd, 2 - (work & 3), 0).astype(np.int64)
        digits[p] = d.astype(np.int8)
        work = (work - d) >> 1
    if not (work == 0).all():
        raise ValueError(f"values exceed {bits}-bit CSD range")
    return digits


def csd_planes(w_q: np.ndarray, bits: int = 4) -> np.ndarray:
    """Digit planes for a quantized weight matrix: int8 [bits, K, N]."""
    return csd_digits(w_q, bits)


def csd_nonzero_digits(w_q: np.ndarray, bits: int = 4) -> np.ndarray:
    """Per-weight adder count (number of non-zero CSD digits)."""
    return (csd_digits(w_q, bits) != 0).sum(axis=0)


def pruned_fraction(w_q: np.ndarray) -> float:
    """Fraction of weights whose MAC unit is eliminated (paper: 15-25%)."""
    return float((w_q == 0).mean())
