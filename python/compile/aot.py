"""AOT compile path: JAX device blocks -> HLO text + weight blobs.

Run once per config (``make artifacts``); the rust runtime is self-contained
afterwards. Python never executes on the request path.

Outputs, per config, under ``artifacts/<config>/``:

  MANIFEST.txt   line-oriented manifest (parsed by rust/src/runtime/manifest.rs)
  weights.bin    concatenated little-endian blobs (f32 / int8)
  programs/*.hlo.txt  one HLO-text program per (block, bucket, variant[, layer])

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Weight modes:
  baked — weights are HLO constants (One-Model-One-Chip cartridge); programs
          are per-layer. Used for `tiny`.
  args  — weights are program parameters uploaded once by the runtime and
          kept resident as PJRT buffers (paper Section VII-D hybrid mode).
          Programs are shared across layers (same shapes!), so a 14-layer
          model needs only 3 programs per (bucket, variant).
"""

import argparse
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, quantize
from .configs import CONFIGS, BUILDABLE, ModelConfig
from .kernels.ref import recompose

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    print_large_constants=True is essential for baked (OMOC) programs: the
    default printer elides big weight constants as `{...}`, which the rust
    side would happily parse into NaN/zero garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


# ---------------------------------------------------------------------------
# deterministic synthetic weights
# ---------------------------------------------------------------------------

def _rng(cfg: ModelConfig, layer: int, slot: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=[cfg.seed * 1_000_003 + layer, slot]))


def gen_layer_weights(cfg: ModelConfig, layer: int) -> dict:
    """Raw f32 weights for one transformer layer, N(0, 1/sqrt(K))."""
    d, f = cfg.d_model, cfg.d_ffn
    def mat(slot, k, n):
        return (_rng(cfg, layer, slot).standard_normal((k, n), dtype=np.float32)
                / np.float32(np.sqrt(k)))
    return {
        "g1": np.ones(d, np.float32),
        "wqkv": mat(0, d, 3 * d),
        "g2": np.ones(d, np.float32),
        "wo": mat(1, d, d),
        "w1": mat(2, d, f),
        "w3": mat(3, d, f),
        "w2": mat(4, f, d),
    }


def gen_final_weights(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    we = (_rng(cfg, cfg.n_layers, 0).standard_normal((d, v), dtype=np.float32)
          / np.float32(np.sqrt(d)))
    return {"gf": np.ones(d, np.float32), "we": we}


# ---------------------------------------------------------------------------
# blob store
# ---------------------------------------------------------------------------

class BlobStore:
    """Append-only little-endian blob file + manifest entries."""

    def __init__(self, path: str):
        self.path = path
        self.f = open(path, "wb")
        self.offset = 0
        self.entries = []  # (name, dtype, shape, offset, nbytes)

    def add(self, name: str, arr: np.ndarray) -> str:
        dtype = {"float32": "f32", "int8": "i8"}[arr.dtype.name]
        data = np.ascontiguousarray(arr).tobytes()
        self.entries.append((name, dtype, arr.shape, self.offset, len(data)))
        self.f.write(data)
        self.offset += len(data)
        return name

    def close(self):
        self.f.close()

    def manifest_lines(self):
        for name, dtype, shape, off, nb in self.entries:
            shp = "x".join(str(s) for s in shape)
            yield f"blob name={name} dtype={dtype} shape={shp} offset={off} nbytes={nb}"


# ---------------------------------------------------------------------------
# per-layer quantized parameter pack
# ---------------------------------------------------------------------------

def quantize_layer(cfg: ModelConfig, raw: dict, with_planes: bool) -> dict:
    """Quantize one layer's weights; returns arrays keyed for blob export."""
    out = {"g1": raw["g1"], "g2": raw["g2"]}
    for key in ("wqkv", "wo", "w1", "w3", "w2"):
        w_q, scale = quantize.quantize_weights(raw[key], bits=cfg.w_bits)
        out[f"{key}_f32"] = recompose(quantize.csd_planes(w_q, cfg.w_bits)).astype(np.float32)
        out[f"{key}_scale"] = scale
        if with_planes:
            out[f"{key}_planes"] = quantize.csd_planes(w_q, cfg.w_bits)
    return out


def quantize_final(cfg: ModelConfig, raw: dict, with_planes: bool) -> dict:
    out = {"gf": raw["gf"]}
    w_q, scale = quantize.quantize_weights(raw["we"], bits=cfg.w_bits)
    out["we_f32"] = w_q.astype(np.float32)
    out["we_scale"] = scale
    if with_planes:
        out["we_planes"] = quantize.csd_planes(w_q, cfg.w_bits)
    # host-side embedding lookup table: dequantized rows of the tied matrix
    out["emb_f32"] = (w_q.astype(np.float32) * scale[None, :]).T.copy()  # [V, D]
    return out


def weight_for_variant(pack: dict, key: str, variant: str):
    return pack[f"{key}_planes"] if variant == "csd" else pack[f"{key}_f32"]


# ---------------------------------------------------------------------------
# program lowering
# ---------------------------------------------------------------------------

def _spec(arr_or_shape, dtype=None):
    if isinstance(arr_or_shape, np.ndarray):
        return jax.ShapeDtypeStruct(arr_or_shape.shape, arr_or_shape.dtype)
    return jax.ShapeDtypeStruct(arr_or_shape, dtype)


def lower_qkv(cfg, bucket, variant, pack=None, baked_pack=None):
    """Returns (hlo_text, arg_blob_keys). pack given => args mode."""
    d = cfg.d_model
    h_spec = _spec((bucket, d), jnp.float32)
    if baked_pack is not None:
        w = weight_for_variant(baked_pack, "wqkv", variant)
        fn = model.make_qkv_fn(d, variant, baked=(
            jnp.asarray(baked_pack["g1"]), jnp.asarray(w), jnp.asarray(baked_pack["wqkv_scale"])))
        return to_hlo_text(jax.jit(fn).lower(h_spec)), []
    w = weight_for_variant(pack, "wqkv", variant)
    fn = model.make_qkv_fn(d, variant)
    lowered = jax.jit(fn).lower(h_spec, _spec(pack["g1"]), _spec(w), _spec(pack["wqkv_scale"]))
    return to_hlo_text(lowered), ["g1", "wqkv", "wqkv_scale"]


def lower_ffn(cfg, bucket, variant, pack=None, baked_pack=None):
    d = cfg.d_model
    h_spec = _spec((bucket, d), jnp.float32)
    a_spec = _spec((bucket, d), jnp.float32)
    keys = ["g2", "wo", "wo_scale", "w1", "w1_scale", "w3", "w3_scale", "w2", "w2_scale"]
    if baked_pack is not None:
        p = baked_pack
        baked = tuple(jnp.asarray(v) for v in (
            p["g2"], weight_for_variant(p, "wo", variant), p["wo_scale"],
            weight_for_variant(p, "w1", variant), p["w1_scale"],
            weight_for_variant(p, "w3", variant), p["w3_scale"],
            weight_for_variant(p, "w2", variant), p["w2_scale"]))
        fn = model.make_ffn_fn(variant, baked=baked)
        return to_hlo_text(jax.jit(fn).lower(h_spec, a_spec)), []
    p = pack
    specs = [h_spec, a_spec, _spec(p["g2"]),
             _spec(weight_for_variant(p, "wo", variant)), _spec(p["wo_scale"]),
             _spec(weight_for_variant(p, "w1", variant)), _spec(p["w1_scale"]),
             _spec(weight_for_variant(p, "w3", variant)), _spec(p["w3_scale"]),
             _spec(weight_for_variant(p, "w2", variant)), _spec(p["w2_scale"])]
    lowered = jax.jit(model.make_ffn_fn(variant)).lower(*specs)
    return to_hlo_text(lowered), keys


def lower_logits(cfg, bucket, variant, pack=None, baked_pack=None):
    d = cfg.d_model
    h_spec = _spec((bucket, d), jnp.float32)
    if baked_pack is not None:
        p = baked_pack
        fn = model.make_logits_fn(variant, baked=(
            jnp.asarray(p["gf"]), jnp.asarray(weight_for_variant(p, "we", variant)),
            jnp.asarray(p["we_scale"])))
        return to_hlo_text(jax.jit(fn).lower(h_spec)), []
    p = pack
    lowered = jax.jit(model.make_logits_fn(variant)).lower(
        h_spec, _spec(p["gf"]), _spec(weight_for_variant(p, "we", variant)), _spec(p["we_scale"]))
    return to_hlo_text(lowered), ["gf", "we", "we_scale"]


# blob key -> manifest blob name for layer i ("wqkv" -> "wqkv_planes_l3"/"wqkv_f32_l3")
def blob_name(key: str, variant: str, layer: int | None) -> str:
    suffix = "" if layer is None else f"_l{layer}"
    if key in ("g1", "g2", "gf") or key.endswith("_scale"):
        return f"{key}{suffix}"
    kind = "planes" if variant == "csd" else "f32"
    return f"{key}_{kind}{suffix}"


# ---------------------------------------------------------------------------
# build driver
# ---------------------------------------------------------------------------

BLOCK_NOUTS = {"qkv": 3, "ffn": 1, "logits": 1}


def build_config(cfg: ModelConfig, out_dir: str, buckets, variants, mode: str):
    cfg_dir = os.path.join(out_dir, cfg.name)
    prog_dir = os.path.join(cfg_dir, "programs")
    os.makedirs(prog_dir, exist_ok=True)

    with_planes = "csd" in variants
    store = BlobStore(os.path.join(cfg_dir, "weights.bin"))
    lines = [
        f"manifest_version {MANIFEST_VERSION}",
        ("config name={name} d_model={d_model} n_layers={n_layers} d_ffn={d_ffn} "
         "n_heads={n_heads} head_dim={head_dim} vocab={vocab} w_bits={w_bits} "
         "a_bits={a_bits} params={params} mode={mode} seed={seed}").format(
            mode=mode, **cfg.to_dict()),
        f"buckets {','.join(str(b) for b in buckets)}",
        f"variants {','.join(variants)}",
    ]

    # ---- weights: quantize + export blobs ----
    packs, pruned = [], []
    for layer in range(cfg.n_layers):
        pack = quantize_layer(cfg, gen_layer_weights(cfg, layer), with_planes)
        packs.append(pack)
        for key, arr in pack.items():
            store.add(blob_name_raw(key, layer), arr)
        pruned.append(float((pack["wqkv_f32"] == 0).mean()))
    fpack = quantize_final(cfg, gen_final_weights(cfg), with_planes)
    for key, arr in fpack.items():
        store.add(blob_name_raw(key, None), arr)
    store.close()
    lines.append(f"pruned_fraction {np.mean(pruned):.4f}")

    # ---- programs ----
    prog_id = 0
    for variant in variants:
        for bucket in buckets:
            if mode == "baked":
                for layer in range(cfg.n_layers):
                    for block, lower in (("qkv", lower_qkv), ("ffn", lower_ffn)):
                        hlo, _ = lower(cfg, bucket, variant, baked_pack=packs[layer])
                        pid = f"p{prog_id}"; prog_id += 1
                        path = f"programs/{block}_{variant}_b{bucket}_l{layer}.hlo.txt"
                        _write(os.path.join(cfg_dir, path), hlo)
                        lines.append(
                            f"program id={pid} path={path} block={block} variant={variant} "
                            f"bucket={bucket} nouts={BLOCK_NOUTS[block]}")
                        lines.append(
                            f"bind layer={layer} block={block} variant={variant} "
                            f"bucket={bucket} program={pid} blobs=-")
                hlo, _ = lower_logits(cfg, bucket, variant, baked_pack=fpack)
                pid = f"p{prog_id}"; prog_id += 1
                path = f"programs/logits_{variant}_b{bucket}.hlo.txt"
                _write(os.path.join(cfg_dir, path), hlo)
                lines.append(f"program id={pid} path={path} block=logits variant={variant} "
                             f"bucket={bucket} nouts=1")
                lines.append(f"bind layer=-1 block=logits variant={variant} bucket={bucket} "
                             f"program={pid} blobs=-")
            else:  # args mode: one program per block shared across layers
                for block, lower in (("qkv", lower_qkv), ("ffn", lower_ffn)):
                    hlo, keys = lower(cfg, bucket, variant, pack=packs[0])
                    pid = f"p{prog_id}"; prog_id += 1
                    path = f"programs/{block}_{variant}_b{bucket}.hlo.txt"
                    _write(os.path.join(cfg_dir, path), hlo)
                    lines.append(f"program id={pid} path={path} block={block} variant={variant} "
                                 f"bucket={bucket} nouts={BLOCK_NOUTS[block]}")
                    for layer in range(cfg.n_layers):
                        blobs = ",".join(blob_name(k, variant, layer) for k in keys)
                        lines.append(f"bind layer={layer} block={block} variant={variant} "
                                     f"bucket={bucket} program={pid} blobs={blobs}")
                hlo, keys = lower_logits(cfg, bucket, variant, pack=fpack)
                pid = f"p{prog_id}"; prog_id += 1
                path = f"programs/logits_{variant}_b{bucket}.hlo.txt"
                _write(os.path.join(cfg_dir, path), hlo)
                blobs = ",".join(blob_name(k, variant, None) for k in keys)
                lines.append(f"program id={pid} path={path} block=logits variant={variant} "
                             f"bucket={bucket} nouts=1")
                lines.append(f"bind layer=-1 block=logits variant={variant} bucket={bucket} "
                             f"program={pid} blobs={blobs}")

    lines.extend(store.manifest_lines())
    _write(os.path.join(cfg_dir, "MANIFEST.txt"), "\n".join(lines) + "\n")
    print(f"[aot] {cfg.name}: {prog_id} programs, "
          f"{store.offset / 1e6:.1f} MB weights, pruned={np.mean(pruned):.1%}")


def blob_name_raw(key: str, layer: int | None) -> str:
    """Manifest blob name for a pack key (packs already encode planes/f32)."""
    suffix = "" if layer is None else f"_l{layer}"
    return f"{key}{suffix}"


def _write(path: str, text: str):
    with open(path, "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--configs", default="tiny,demo-100m")
    ap.add_argument("--buckets", default=None, help="comma-separated batch buckets")
    ap.add_argument("--variants", default=None, help="fused,csd")
    args = ap.parse_args()

    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        if name not in BUILDABLE:
            print(f"[aot] skipping analytic-only config {name}", file=sys.stderr)
            continue
        if name == "tiny":
            buckets = [int(b) for b in (args.buckets or "1,2,4").split(",")]
            variants = (args.variants or "fused,csd").split(",")
            mode = "baked"
        else:
            buckets = [int(b) for b in (args.buckets or "1,2,4,8").split(",")]
            variants = (args.variants or "fused").split(",")
            mode = "args"
        build_config(cfg, args.out, buckets, variants, mode)


if __name__ == "__main__":
    main()
