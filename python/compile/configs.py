"""Model topologies for the ITA reproduction.

Two kinds of configs:

* **Buildable** configs (``tiny``, ``demo-100m``) — artifacts are AOT-lowered
  and served end-to-end by the rust coordinator.
* **Analytic** configs (``tinyllama-1.1b``, ``llama2-7b``, ``llama2-13b``) —
  the paper's target topologies, used by the rust-side area / cost / energy /
  bandwidth models (Tables II-V, Eq. 7-11). They are never lowered: baking
  7B INT4 weights into HLO text is exactly the thing the paper calls a
  520-3680 mm^2 die, not a CI job.

The paper's bandwidth arithmetic (Section VI-C) uses d_model=4096, 32 layers,
vocab 32000 == ``llama2-7b`` here.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    d_ffn: int
    n_heads: int
    vocab: int
    # quantization
    w_bits: int = 4  # INT4 hardwired weights (paper Section V-C)
    a_bits: int = 8  # INT8 activations
    # weight-generation seed (synthetic, deterministic)
    seed: int = 0x17A

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def params(self) -> int:
        """Total parameter count (weights hardwired on the ITA device +
        host-side embedding table)."""
        per_layer = (
            3 * self.d_model * self.d_model  # Wq, Wk, Wv
            + self.d_model * self.d_model    # Wo
            + 3 * self.d_model * self.d_ffn  # W1, W3, W2 (SwiGLU)
            + 2 * self.d_model               # rmsnorm gains
        )
        final = self.d_model  # final norm
        emb = self.vocab * self.d_model  # tied embedding / LM head
        return self.n_layers * per_layer + final + emb

    def device_params(self) -> int:
        """Parameters physically encoded on the ITA die. The LM head is
        on-device (the paper's device emits final logits, Eq. 9); the host
        keeps its own copy of the tied embedding matrix for the lookup, so
        the device carries every parameter."""
        return self.params()

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["params"] = self.params()
        return d


CONFIGS = {
    # readable-in-seconds config; weights baked as HLO constants (true OMOC)
    "tiny": ModelConfig("tiny", d_model=64, n_layers=2, d_ffn=192, n_heads=4, vocab=258),
    # ~100M-parameter end-to-end serving config; weights passed as device
    # buffers loaded once at startup (hybrid/SRAM mode, Section VII-D)
    "demo-100m": ModelConfig("demo-100m", d_model=768, n_layers=14, d_ffn=2048, n_heads=12, vocab=258),
    # analytic topologies (paper Table IV)
    "tinyllama-1.1b": ModelConfig("tinyllama-1.1b", d_model=2048, n_layers=22, d_ffn=5632, n_heads=32, vocab=32000),
    "llama2-7b": ModelConfig("llama2-7b", d_model=4096, n_layers=32, d_ffn=11008, n_heads=32, vocab=32000),
    "llama2-13b": ModelConfig("llama2-13b", d_model=5120, n_layers=40, d_ffn=13824, n_heads=40, vocab=32000),
}

BUILDABLE = ("tiny", "demo-100m")
