"""L1 Pallas kernels: the ITA device's hardwired matrix-vector hot-spot.

Two kernels, both lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls; see /opt/xla-example/README.md):

* ``csd_matmul`` — the paper-structural kernel. INT8 activations contracted
  against CSD digit planes:  acc = sum_p (x @ D_p) << p  in int32. This *is*
  the shift-add tree of Section IV-C in tensor form: each plane-p contraction
  is the set of adders whose shift amount is p; a zero digit contributes
  nothing, exactly like a pruned adder.

* ``fused_matmul`` — the performance kernel. The digit planes are recomposed
  to an integer-valued f32 matrix at build time and contracted with one f32
  GEMM. Because |acc| < 2^24 for every topology we build (K <= 2048,
  |x| <= 127, |w| <= 7 -> |acc| <= 127*7*2048 = 1,820,672), the f32 product
  is **bit-exact** equal to the int32 shift-add result. pytest asserts this.

Block sizes: on CPU-PJRT we lower a single block (whole operand in "VMEM") —
grid loops under interpret=True become HLO while-loops that defeat the
backend GEMM. The tiled variants (block_n) exist to express and test the
HBM<->VMEM schedule that a real TPU lowering would use; DESIGN.md §Perf
derives the VMEM footprint and MXU utilization estimates from these specs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _csd_kernel(x_ref, p_ref, o_ref, *, n_planes: int):
    """acc = sum_p (x @ D_p) << p, int32 accumulation."""
    x = x_ref[...].astype(jnp.int32)
    acc = jnp.zeros((x.shape[0], p_ref.shape[2]), jnp.int32)
    for p in range(n_planes):  # static unroll: one "adder rank" per plane
        d = p_ref[p].astype(jnp.int32)
        contrib = jax.lax.dot_general(
            x, d, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        acc = acc + (contrib << p)
    o_ref[...] = acc


def csd_matmul(x_q, planes, *, block_n: int | None = None, interpret: bool = True):
    """INT8 x CSD-plane matmul.

    Args:
      x_q: int8 [B, K] quantized activations.
      planes: int8 [P, K, N] digit planes (values in {-1, 0, +1}).
      block_n: optional output-column tile (TPU-schedule expression); None
        lowers one whole-array block (CPU artifact default).

    Returns:
      int32 [B, N] == x_q @ (sum_p planes[p] << p), exactly.
    """
    b, k = x_q.shape
    n_planes, k2, n = planes.shape
    assert k == k2, (k, k2)
    kern = functools.partial(_csd_kernel, n_planes=n_planes)
    out_shape = jax.ShapeDtypeStruct((b, n), jnp.int32)
    if block_n is None:
        return pl.pallas_call(kern, out_shape=out_shape, interpret=interpret)(x_q, planes)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),           # x stays resident
            pl.BlockSpec((n_planes, k, block_n), lambda j: (0, 0, j)),  # stream planes
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda j: (0, j)),
        interpret=interpret,
    )(x_q, planes)


def _fused_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def fused_matmul(x, w, *, block_n: int | None = None, interpret: bool = True):
    """f32 GEMM over integer-valued operands (bit-exact vs csd_matmul).

    Args:
      x: f32 [B, K] — integer-valued (quantized activations cast to f32).
      w: f32 [K, N] — integer-valued (recomposed quantized weights).
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2
    out_shape = jax.ShapeDtypeStruct((b, n), jnp.float32)
    if block_n is None:
        return pl.pallas_call(_fused_kernel, out_shape=out_shape, interpret=interpret)(x, w)
    assert n % block_n == 0
    return pl.pallas_call(
        _fused_kernel,
        out_shape=out_shape,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((k, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda j: (0, j)),
        interpret=interpret,
    )(x, w)


def vmem_footprint_bytes(b: int, k: int, n: int, n_planes: int = 4,
                         block_n: int | None = None, variant: str = "csd") -> int:
    """VMEM bytes one grid step touches — the §Perf TPU-estimate input.

    csd: x tile (b*k, int8) + plane tile (n_planes*k*bn, int8) + acc (b*bn, i32)
    fused: x tile (b*k, f32) + w tile (k*bn, f32) + acc (b*bn, f32)
    """
    bn = block_n or n
    if variant == "csd":
        return b * k + n_planes * k * bn + 4 * b * bn
    return 4 * (b * k + k * bn + b * bn)


def mxu_utilization_estimate(b: int, k: int, n: int, variant: str = "csd") -> float:
    """Fraction of 128x128 MXU lanes doing useful work per pass.

    The MXU processes ceil-padded tiles; tiny batch dims waste rows. For the
    csd variant each plane is a separate pass, so utilization matches the
    fused variant per pass but total passes are n_planes x.
    """
    pad = lambda v, m: -(-v // m) * m
    useful = b * k * n
    padded = pad(b, 128) * pad(k, 128) * pad(n, 128)
    return useful / padded
