"""Pure-jnp correctness oracles for the Pallas kernels and device blocks.

Every kernel and every AOT-lowered device block has a reference here; pytest
asserts exact (integer paths) or allclose (float paths) agreement. The rust
`device::sim` module mirrors these same formulas so the served engine can be
differential-tested against a second, independent implementation.
"""

import jax
import jax.numpy as jnp
import numpy as np


def recompose(planes: np.ndarray) -> np.ndarray:
    """sum_p planes[p] << p — inverse of quantize.csd_planes. int32 [K, N]."""
    n_planes = planes.shape[0]
    acc = np.zeros(planes.shape[1:], np.int32)
    for p in range(n_planes):
        acc += planes[p].astype(np.int32) << p
    return acc


def ref_int_matmul(x_q: np.ndarray, w_q: np.ndarray) -> np.ndarray:
    """Exact integer matmul oracle: int32 [B, N]."""
    return x_q.astype(np.int32) @ w_q.astype(np.int32)


# --- device-block reference ops (match model.py exactly, shapes [B, ...]) ---

RMS_EPS = 1e-5


def rmsnorm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + RMS_EPS) * g


def quant_act(x, a_bits: int = 8):
    """Per-row symmetric activation quantization. Returns (q int8, scale)."""
    q = (1 << (a_bits - 1)) - 1
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / q
    s = jnp.maximum(s, 1e-8)
    xq = jnp.clip(jnp.round(x / s), -q, q).astype(jnp.int8)
    return xq, s


def qlinear_ref(x, w_q, w_scale):
    """Quantize -> exact int matmul -> dequantize (oracle for both kernels)."""
    xq, xs = quant_act(x)
    acc = xq.astype(jnp.int32) @ w_q.astype(jnp.int32)
    return acc.astype(jnp.float32) * xs * w_scale[None, :]


def silu(x):
    return x * jax.nn.sigmoid(x)


def qkv_block_ref(h, g1, w_q, w_scale, d_model: int):
    x = rmsnorm(h, g1)
    qkv = qlinear_ref(x, w_q, w_scale)
    return qkv[:, :d_model], qkv[:, d_model:2 * d_model], qkv[:, 2 * d_model:]


def ffn_block_ref(h, attn, g2, wo_q, wo_s, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s):
    h = h + qlinear_ref(attn, wo_q, wo_s)
    x = rmsnorm(h, g2)
    a = qlinear_ref(x, w1_q, w1_s)
    b = qlinear_ref(x, w3_q, w3_s)
    return h + qlinear_ref(silu(a) * b, w2_q, w2_s)


def logits_block_ref(h, gf, we_q, we_s):
    x = rmsnorm(h, gf)
    return qlinear_ref(x, we_q, we_s)
