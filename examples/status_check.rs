//! End-to-end checker for the live status surface: boots a real
//! `serve_fleet --status-port 0` sibling process, waits for its port
//! announcement, then validates the three endpoints while the fleet is
//! serving:
//!
//! * `/status` — well-formed `ita-status-v1` JSON: schema tag, numeric
//!   `wall_s`/`queued`/`urgent`, a non-empty `cartridges` array with the
//!   occupancy fields, `queues`/`alerts`/`tenants` arrays, and the
//!   flight-recorder `trace` object;
//! * `/metrics` — Prometheus text-format lint (metric-name and label
//!   syntax, parseable sample values, no duplicate series), scraped twice
//!   to assert counter monotonicity across scrapes;
//! * `/trace` — valid JSON with a `recent` event array and a `dropped`
//!   count.
//!
//! Used by `make status-check` and CI; the endpoint contract is documented
//! in `docs/observability.md`.
//!
//!     cargo run --release --example status_check

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use ita::util::json::{parse, JsonValue};

/// Counter-like series (by substring of the metric name) that must never
/// decrease between two scrapes of one live fleet.
const COUNTERS: [&str; 8] = [
    "requests_completed",
    "tokens_generated",
    "shed",
    "cancelled",
    "requeued",
    "migrations",
    "admitted",
    "trace_dropped_total",
];

/// One-shot HTTP/1.1 GET against the status endpoint; returns the body of
/// a 200 response.
fn http_get(addr: &str, path: &str) -> Result<String> {
    let mut conn = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    conn.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(conn, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw).with_context(|| format!("reading GET {path}"))?;
    let (head, body) =
        raw.split_once("\r\n\r\n").with_context(|| format!("GET {path}: no header/body split"))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains("200") {
        bail!("GET {path}: {status}");
    }
    Ok(body.to_string())
}

fn num(v: &JsonValue, key: &str, what: &str) -> Result<f64> {
    v.get(key)
        .and_then(JsonValue::as_f64)
        .with_context(|| format!("{what} missing numeric {key:?}"))
}

/// Validate the `/status` document; returns (cartridges, alerts, tenants).
fn check_status(body: &str) -> Result<(usize, usize, usize)> {
    let root = parse(body).context("/status is not valid JSON")?;
    match root.get("schema").and_then(JsonValue::as_str) {
        Some("ita-status-v1") => {}
        other => bail!("unexpected status schema {other:?}"),
    }
    for key in ["wall_s", "queued", "urgent"] {
        num(&root, key, "status")?;
    }
    // present but possibly null until the fleet has drained anything
    root.get("drain_rate_cost_per_s").context("status missing drain_rate_cost_per_s")?;
    let cartridges = root
        .get("cartridges")
        .and_then(JsonValue::as_array)
        .context("status has no cartridges array")?;
    if cartridges.is_empty() {
        bail!("status reports zero cartridges");
    }
    for (i, c) in cartridges.iter().enumerate() {
        let what = format!("cartridge {i}");
        for key in ["cartridge", "in_flight", "capacity", "active_rows"] {
            num(c, key, &what)?;
        }
        match c.get("alive") {
            Some(JsonValue::Bool(_)) => {}
            other => bail!("{what} has non-bool alive: {other:?}"),
        }
    }
    for key in ["queues", "alerts", "tenants"] {
        root.get(key)
            .and_then(JsonValue::as_array)
            .with_context(|| format!("status has no {key} array"))?;
    }
    let trace = root.get("trace").context("status has no trace object")?;
    trace.get("recent").and_then(JsonValue::as_array).context("trace has no recent array")?;
    num(trace, "dropped", "trace")?;
    let alerts = root.get("alerts").and_then(JsonValue::as_array).unwrap_or(&[]).len();
    let tenants = root.get("tenants").and_then(JsonValue::as_array).unwrap_or(&[]).len();
    Ok((cartridges.len(), alerts, tenants))
}

/// Syntax-check one sample's series part: `name` or `name{k="v",...}`.
fn check_series_syntax(s: &str) -> Result<()> {
    let (name, labels) = match s.split_once('{') {
        Some((n, rest)) => (n, Some(rest.strip_suffix('}').context("unterminated label set")?)),
        None => (s, None),
    };
    let name_ok = !name.is_empty()
        && !name.starts_with(|c: char| c.is_ascii_digit())
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
    if !name_ok {
        bail!("bad metric name {name:?}");
    }
    if let Some(labels) = labels {
        // none of our label values embed ',' or '=', so plain splits lint them
        for pair in labels.split(',') {
            let (k, v) =
                pair.split_once('=').with_context(|| format!("label {pair:?} has no '='"))?;
            let key_ok = !k.is_empty()
                && !k.starts_with(|c: char| c.is_ascii_digit())
                && k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
            if !key_ok {
                bail!("bad label name {k:?}");
            }
            if v.len() < 2 || !v.starts_with('"') || !v.ends_with('"') {
                bail!("label value {v} is not double-quoted");
            }
        }
    }
    Ok(())
}

/// Lint one `/metrics` exposition and index it as series → value.
fn lint_prometheus(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut series = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let ctx = || format!("metrics line {}: {line:?}", i + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            match rest.split_whitespace().next() {
                Some("HELP") | Some("TYPE") => continue,
                _ => bail!("{}: unexpected comment form", ctx()),
            }
        }
        let (key, value) = line.rsplit_once(' ').with_context(|| format!("{}: no value", ctx()))?;
        value.parse::<f64>().with_context(|| format!("{}: unparseable value", ctx()))?;
        check_series_syntax(key).with_context(&ctx)?;
        if series.insert(key.to_string(), value.parse::<f64>().unwrap()).is_some() {
            bail!("{}: duplicate series", ctx());
        }
    }
    if !series.keys().any(|k| k.starts_with("ita_")) {
        bail!("exposition carries no ita_ series");
    }
    Ok(series)
}

fn main() -> Result<()> {
    // the sibling binary cargo built alongside this example
    let exe = std::env::current_exe().context("locating status_check binary")?;
    let server = exe.parent().context("no parent dir")?.join("serve_fleet");
    if !server.exists() {
        bail!("{} not found — build it first (make status-check does)", server.display());
    }

    let mut child = std::process::Command::new(&server)
        .env("ITA_FLEET_CARTRIDGES", "2")
        .env("ITA_FLEET_REQUESTS", "12")
        .env("ITA_FLEET_TOKENS", "8")
        .env("ITA_FLEET_STATUS_PORT", "0")
        .env("ITA_FLEET_STATUS_LINGER_MS", "8000")
        .env("ITA_FLEET_SLO_ITL_MS", "50")
        .env("ITA_FLEET_SLO_AVAILABILITY", "0.99")
        .env("ITA_FLEET_TRACE_TAIL", "16384")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .context("spawning serve_fleet")?;
    let result = run_checks(&mut child);
    let _ = child.kill();
    let _ = child.wait();
    result
}

fn run_checks(child: &mut std::process::Child) -> Result<()> {
    let stdout = child.stdout.take().context("child stdout not piped")?;
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = match lines.next() {
            Some(l) => l.context("reading serve_fleet stdout")?,
            None => bail!("serve_fleet exited before announcing the status port"),
        };
        if let Some(rest) = line.strip_prefix("status: listening on http://") {
            break rest.trim().to_string();
        }
    };
    // keep draining the pipe so the child never blocks on a full buffer
    std::thread::spawn(move || {
        for _ in lines.flatten() {}
    });

    let (cartridges, alerts, tenants) = check_status(&http_get(&addr, "/status")?)?;
    println!(
        "status-check: /status ok ({cartridges} cartridges, {alerts} alerts, {tenants} \
         tenant series)"
    );

    let first = lint_prometheus(&http_get(&addr, "/metrics")?)?;
    std::thread::sleep(Duration::from_millis(300));
    let second = lint_prometheus(&http_get(&addr, "/metrics")?)?;
    let mut checked = 0usize;
    for (key, after) in &second {
        let Some(before) = first.get(key) else { continue };
        let name = key.split('{').next().unwrap_or("");
        if COUNTERS.iter().any(|c| name.contains(c)) {
            checked += 1;
            if after < before {
                bail!("counter {key} went backwards across scrapes: {before} -> {after}");
            }
        }
    }
    if checked == 0 {
        bail!("no counter series found to check for monotonicity");
    }
    println!(
        "status-check: /metrics ok ({} series linted, {checked} counters monotonic)",
        second.len()
    );

    let trace = parse(&http_get(&addr, "/trace")?).context("/trace is not valid JSON")?;
    let recent =
        trace.get("recent").and_then(JsonValue::as_array).context("/trace has no recent array")?;
    num(&trace, "dropped", "/trace")?;
    println!("status-check: /trace ok ({} recent events)", recent.len());
    Ok(())
}
