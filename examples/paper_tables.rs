//! Regenerate every table and figure from the paper's evaluation section
//! (Tables I–VIII, Figs 2–3), printing ours next to the paper's published
//! values.
//!
//!     cargo run --release --example paper_tables

fn main() {
    println!("ITA reproduction — paper tables/figures (ours vs paper)\n");
    for report in ita::report::all_reports() {
        report.print();
        println!();
    }
    println!(
        "See EXPERIMENTS.md for the paper-vs-measured discussion and the\n\
         deviations each `note:` line flags."
    );
}
