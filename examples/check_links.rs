//! Dead-link checker for the repo's markdown docs (`make docs-check`).
//!
//!     cargo run --release --example check_links [-- file.md ...]
//!
//! With no arguments it scans `README.md`, `rust/src/coordinator/README.md`,
//! and every `docs/*.md`. For each markdown link `[text](target)` whose
//! target is *relative* (no scheme, not a pure `#fragment`), the target —
//! minus any fragment — must exist on disk relative to the file containing
//! the link. Exits nonzero listing every dead link, so doc restructures
//! that orphan a cross-reference fail CI rather than shipping.

use std::path::{Path, PathBuf};

/// Extract every `](target)` link target from markdown text.
fn links(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            if let Some(end) = text[i + 2..].find(')') {
                out.push(text[i + 2..i + 2 + end].to_string());
                i += 2 + end;
                continue;
            }
        }
        i += 1;
    }
    out
}

fn default_files() -> Vec<PathBuf> {
    let mut v =
        vec![PathBuf::from("README.md"), PathBuf::from("rust/src/coordinator/README.md")];
    if let Ok(rd) = std::fs::read_dir("docs") {
        for e in rd.flatten() {
            let p = e.path();
            if p.extension().is_some_and(|x| x == "md") {
                v.push(p);
            }
        }
    }
    v.sort();
    v
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let files: Vec<PathBuf> = if args.is_empty() {
        default_files()
    } else {
        args.into_iter().map(PathBuf::from).collect()
    };
    let mut checked = 0usize;
    let mut dead = 0usize;
    for f in &files {
        let text = match std::fs::read_to_string(f) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("check-links: cannot read {}: {e}", f.display());
                std::process::exit(2);
            }
        };
        let base = f.parent().unwrap_or_else(|| Path::new("."));
        for raw in links(&text) {
            // `](path "title")` → path; skip absolute/external/fragment-only
            let target = raw.split_whitespace().next().unwrap_or("");
            if target.is_empty()
                || target.starts_with('#')
                || target.starts_with("mailto:")
                || target.contains("://")
            {
                continue;
            }
            let path_part = target.split('#').next().unwrap_or("");
            if path_part.is_empty() {
                continue;
            }
            checked += 1;
            let resolved = base.join(path_part);
            if !resolved.exists() {
                dead += 1;
                eprintln!(
                    "check-links: dead link in {}: ({target}) -> {}",
                    f.display(),
                    resolved.display()
                );
            }
        }
    }
    println!(
        "check-links: {checked} relative links across {} files, {dead} dead",
        files.len()
    );
    if dead > 0 {
        std::process::exit(1);
    }
}
