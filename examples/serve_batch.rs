//! End-to-end validation driver (DESIGN.md §7): serve a batched workload of
//! concurrent generation requests against the ~100M-parameter `demo-100m`
//! artifacts through the full stack — PJRT device, continuous batching,
//! paged KV cache, host attention — and report latency/throughput,
//! interface traffic (checked against the paper's Eq. 7–11 model scaled to
//! this topology), and modeled device energy.
//!
//!     make artifacts && cargo run --release --example serve_batch
//!     [ITA_SERVE_CONFIG=tiny] [ITA_SERVE_REQUESTS=16] [ITA_SERVE_TOKENS=24]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::Result;

use ita::config::ModelConfig;
use ita::coordinator::engine::Engine;
use ita::coordinator::workload::{self, WorkloadSpec};
use ita::coordinator::scheduler::SchedulerOpts;
use ita::coordinator::server::Server;
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::energy::EnergyParams;
use ita::host::embedding::EmbeddingTable;
use ita::interface::TokenTraffic;
use ita::runtime::weights::load_artifacts;

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let config = std::env::var("ITA_SERVE_CONFIG").unwrap_or_else(|_| "demo-100m".into());
    let n_requests = env_or("ITA_SERVE_REQUESTS", 16);
    let max_tokens = env_or("ITA_SERVE_TOKENS", 24);

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&config);
    anyhow::ensure!(
        dir.join("MANIFEST.txt").exists(),
        "artifacts/{config} missing — run `make artifacts`"
    );

    println!("== ITA end-to-end serving driver ==");
    println!("config={config} requests={n_requests} max_new_tokens={max_tokens}\n");

    let dir2 = dir.clone();
    let t_boot = Instant::now();
    let server = Server::start(
        move || {
            let (m, s) = load_artifacts(&dir2)?;
            let n_heads = m.n_heads;
            let sim = SimDevice::load(&m, &s)?;
            let emb = EmbeddingTable::new(sim.weights().emb.clone());
            let dev = PjrtDevice::load(m, &s, "fused")?;
            eprintln!(
                "[boot] {} programs compiled, {} weight buffers resident",
                dev.runtime().n_programs(),
                dev.runtime().n_weight_buffers()
            );
            Ok(Engine::new(Box::new(dev), emb, n_heads))
        },
        SchedulerOpts::default(),
    )?;
    println!("server up in {:.1}s (compile + weight upload, one-time)", t_boot.elapsed().as_secs_f64());

    // deterministic synthetic workload: Poisson arrivals @20 req/s,
    // varied prompt/output lengths (coordinator::workload)
    let spec = WorkloadSpec {
        n_requests,
        output_len: (max_tokens / 2, max_tokens),
        ..WorkloadSpec::e2e_default(n_requests)
    };
    let timed = workload::generate(&spec);
    let wstats = workload::stats(&timed);
    println!(
        "workload: {} requests over {:.1}s, {} prompt tokens, ≤{} output tokens",
        n_requests, wstats.duration_s, wstats.total_prompt_tokens, wstats.total_output_budget
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, tr) in timed.into_iter().enumerate() {
        let wait = tr.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        handles.push((i, server.submit(tr.request)));
    }

    let mut total_tokens = 0usize;
    for (i, h) in handles {
        let r = h.wait()?;
        total_tokens += r.tokens.len();
        if i < 3 {
            println!(
                "req {i}: {} prompt + {} generated tokens, ttft {:.0} ms, itl {:.1} ms",
                r.prompt_tokens,
                r.tokens.len(),
                r.ttft_s * 1e3,
                r.itl_s * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown()?;

    println!("\n== results ==");
    println!("{}", m.report());
    println!(
        "end-to-end: {total_tokens} tokens in {wall:.1}s = {:.1} tok/s aggregate",
        total_tokens as f64 / wall
    );

    // check measured interface traffic against the paper's analytical model
    if let Some(cfg) = ModelConfig::by_name(&config) {
        let per_tok = TokenTraffic::full_mode(cfg);
        let analytic = per_tok.total_bytes() as f64
            * (m.tokens_generated + m.tokens_prefilled) as f64;
        println!(
            "interface traffic: measured {:.1} MB vs Eq.7-11 (full mode, scaled) {:.1} MB ({:+.0}%)\n\
             (the +delta is the per-layer h crossings of our two-program device; a \
             physical ITA chains layers on-die — see TrafficLedger docs)",
            m.interface_bytes as f64 / 1e6,
            analytic / 1e6,
            (m.interface_bytes as f64 / analytic - 1.0) * 100.0
        );
        let e = EnergyParams::default();
        println!(
            "modeled ITA device energy: {:.2} J ({:.1} mJ/token) — a GPU INT8 device \
             moving these weights from DRAM would burn {:.1}x more (Table II)",
            m.modeled_device_energy_j(e.ita().total_pj()),
            m.modeled_device_energy_j(e.ita().total_pj()) * 1e3
                / (m.tokens_generated + m.tokens_prefilled).max(1) as f64,
            e.improvement_vs_int8(),
        );
    }
    Ok(())
}
