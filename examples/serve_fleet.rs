//! Multi-cartridge serving driver: shard a deterministic synthetic workload
//! across a fleet of simulated ITA cartridges behind the streaming front
//! door, then reconcile fleet-level metrics against the per-cartridge
//! breakdowns (the paper's Eq. 7–11 interface accounting stays per-device).
//!
//!     cargo run --release --example serve_fleet -- [--trace out.json]
//!     [--metrics metrics.json] [--status-port 9090]
//!     [ITA_FLEET_CARTRIDGES=4] [ITA_FLEET_REQUESTS=32] [ITA_FLEET_TOKENS=16]
//!     [ITA_FLEET_DISPATCH=affinity|least-loaded|rebalance|energy]
//!     [ITA_FLEET_TRACE=out.json] [ITA_FLEET_METRICS=metrics.json]
//!     [ITA_FLEET_TARGET_ITL_MS=10] [ITA_FLEET_QUEUE_BUDGET_MS=250]
//!     [ITA_FLEET_ADAPTIVE_PREFILL=1]
//!     [ITA_FLEET_STATUS_PORT=9090] [ITA_FLEET_STATUS_LINGER_MS=0]
//!     [ITA_FLEET_SLO_ITL_MS=50] [ITA_FLEET_SLO_AVAILABILITY=0.999]
//!     [ITA_FLEET_TRACE_TAIL=16384]
//!
//! Runs artifact-free: each cartridge is an `Engine::synthetic` SimDevice
//! (identical weights per cartridge, as if N copies of one neural cartridge
//! were plugged into one host — the paper's one-model-one-chip deployment).
//! The workload draws prompts from a small corpus, so repeated prefixes hit
//! each cartridge's radix prefix cache; the default `affinity` dispatch
//! routes shared prefixes onto the cartridge already holding them.
//!
//! Requests go through the streaming [`FrontDoor`]: every submission gets a
//! token stream that the driver drains incrementally and checks against the
//! final result (exactly-once delivery). The SLO knobs are **off by
//! default** — set `ITA_FLEET_QUEUE_BUDGET_MS` / `ITA_FLEET_TARGET_ITL_MS`
//! to watch admission control shed and the adaptive prefill budget
//! retarget under overload. The full contract is
//! `docs/serving-front-door.md`.
//!
//! With `--trace` the fleet records every request's lifecycle (admit, queue
//! wait, prefill chunks, waves, speculation, checkpoint/migrate, complete)
//! and writes a Chrome/Perfetto `trace_events` JSON — open it at
//! <https://ui.perfetto.dev>. With `--metrics` it writes the unified
//! `MetricsRegistry` snapshot as JSON plus a Prometheus text exposition at
//! `<path>.prom`. See `docs/observability.md`.
//!
//! With `--status-port` (or `ITA_FLEET_STATUS_PORT`; port `0` = ephemeral)
//! a dependency-free HTTP endpoint serves the live observability plane
//! while the workload runs: `/metrics` (Prometheus text), `/status`
//! (positional `StatusSnapshot` JSON), `/trace` (flight-recorder tail).
//! `ITA_FLEET_SLO_ITL_MS` / `ITA_FLEET_SLO_AVAILABILITY` declare SLOs for
//! burn-rate alerting, `ITA_FLEET_TRACE_TAIL` switches tracing to
//! tail-based sampling under that event budget, and
//! `ITA_FLEET_STATUS_LINGER_MS` keeps the endpoint up after the workload
//! drains (for scrapers — see `examples/status_check.rs`). All of it is
//! **off by default**.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use ita::config::ModelConfig;
use ita::coordinator::engine::Engine;
use ita::coordinator::fleet::{Dispatch, EnergyAware, LeastLoaded, PrefixAffinity, Rebalance};
use ita::coordinator::frontdoor::{FrontDoor, FrontDoorOpts, SubmitError};
use ita::coordinator::metrics::MetricsRegistry;
use ita::coordinator::scheduler::SchedulerOpts;
use ita::coordinator::stream::StreamItem;
use ita::coordinator::telemetry::SloSpec;
use ita::coordinator::workload::{self, Arrivals, TimedRequest, WorkloadSpec};

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_ms(key: &str) -> Option<f64> {
    std::env::var(key).ok().and_then(|v| v.parse::<f64>().ok()).map(|ms| ms / 1e3)
}

/// `--flag value` from argv, falling back to an environment variable.
fn arg_or_env(flag: &str, env: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var(env).ok())
}

fn main() -> Result<()> {
    let cartridges = env_or("ITA_FLEET_CARTRIDGES", 4).max(1);
    let n_requests = env_or("ITA_FLEET_REQUESTS", 32);
    let max_tokens = env_or("ITA_FLEET_TOKENS", 16);
    let dispatch_name =
        std::env::var("ITA_FLEET_DISPATCH").unwrap_or_else(|_| "affinity".into());
    let dispatch: Box<dyn Dispatch> = match dispatch_name.as_str() {
        "least-loaded" => Box::new(LeastLoaded),
        // prefix-affinity placement + live KV migration off hot cartridges
        "rebalance" => Box::new(Rebalance::new(Box::new(PrefixAffinity::new()))),
        // modeled joules/token routing with thermal backoff
        "energy" => Box::new(EnergyAware::new()),
        _ => Box::new(PrefixAffinity::new()),
    };
    let trace_path = arg_or_env("--trace", "ITA_FLEET_TRACE");
    let metrics_path = arg_or_env("--metrics", "ITA_FLEET_METRICS");
    let status_port: Option<u16> =
        arg_or_env("--status-port", "ITA_FLEET_STATUS_PORT").and_then(|v| v.parse().ok());
    let linger_s = env_ms("ITA_FLEET_STATUS_LINGER_MS").unwrap_or(0.0);
    // SLO knobs — all off by default, so the stock run never sheds or
    // cancels and the trace rail (examples/trace_check.rs) stays exact
    let slo_itl = env_ms("ITA_FLEET_SLO_ITL_MS");
    let slo_avail = std::env::var("ITA_FLEET_SLO_AVAILABILITY")
        .ok()
        .and_then(|v| v.parse::<f64>().ok());
    let slo = (slo_itl.is_some() || slo_avail.is_some()).then(|| SloSpec {
        p99_itl_s: slo_itl,
        availability: slo_avail,
        ..SloSpec::default()
    });
    let door = FrontDoorOpts {
        target_itl_s: env_ms("ITA_FLEET_TARGET_ITL_MS"),
        queue_budget_s: env_ms("ITA_FLEET_QUEUE_BUDGET_MS"),
        adaptive_prefill: std::env::var("ITA_FLEET_ADAPTIVE_PREFILL").is_ok(),
        slo,
        trace_tail_budget: std::env::var("ITA_FLEET_TRACE_TAIL")
            .ok()
            .and_then(|v| v.parse().ok()),
    };

    println!("== ITA fleet serving driver ==");
    println!(
        "cartridges={cartridges} requests={n_requests} max_new_tokens={max_tokens} \
         dispatch={dispatch_name} trace={} target_itl={} queue_budget={}\n",
        trace_path.as_deref().unwrap_or("off"),
        door.target_itl_s.map_or("off".into(), |s| format!("{:.0}ms", s * 1e3)),
        door.queue_budget_s.map_or("off".into(), |s| format!("{:.0}ms", s * 1e3)),
    );

    let mut opts = SchedulerOpts::default();
    if trace_path.is_some() || door.trace_tail_budget.is_some() {
        // per-cartridge ring: plenty for the smoke workloads, drops oldest
        // (and reports the drop count in the trace) if a run outgrows it
        opts.trace_capacity = 1 << 16;
    }

    let t_boot = Instant::now();
    let front = FrontDoor::with_dispatch(
        cartridges,
        |id| {
            // one model, one chip: every cartridge carries the same weights
            let engine = Engine::synthetic(&ModelConfig::TINY, 0x17A);
            eprintln!("[boot] cartridge {id} ready (synthetic tiny weights)");
            Ok(engine)
        },
        opts,
        dispatch,
        door,
    )?;
    println!("fleet up in {:.2}s ({cartridges} cartridges)\n", t_boot.elapsed().as_secs_f64());

    let spec = WorkloadSpec {
        n_requests,
        arrivals: Arrivals::Poisson(50.0),
        output_len: (max_tokens / 2, max_tokens.max(2)),
        ..WorkloadSpec::e2e_default(n_requests)
    };
    let timed = workload::generate(&spec);
    let wstats = workload::stats(&timed);
    println!(
        "workload: {} requests over {:.1}s, {} prompt tokens, ≤{} output tokens",
        n_requests, wstats.duration_s, wstats.total_prompt_tokens, wstats.total_output_budget
    );

    let t0 = Instant::now();
    let mut shed = 0usize;
    let mut total_tokens = 0usize;
    let mut token_batches = 0usize;
    let mut wall = 0.0f64;
    // the status server borrows the front door for the workload's duration
    // (plus the linger window), so both live inside one thread scope
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| -> Result<()> {
        if let Some(port) = status_port {
            let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
            listener.set_nonblocking(true)?;
            // parseable announcement (port 0 binds an ephemeral port);
            // flushed because a piped stdout is block-buffered and
            // scrapers wait on this exact line
            println!("status: listening on http://{}", listener.local_addr()?);
            use std::io::Write as _;
            std::io::stdout().flush()?;
            scope.spawn(|| serve_status(listener, &front, &stop));
        }
        // `stop` is stored on every exit path — an early bail must not
        // leave the server thread spinning past the scope's end
        let run =
            run_workload(&front, timed, t0, &mut shed, &mut total_tokens, &mut token_batches);
        wall = t0.elapsed().as_secs_f64();
        // hold the endpoint open for scrapers before tearing down
        if run.is_ok() && status_port.is_some() && linger_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(linger_s));
        }
        stop.store(true, Ordering::Relaxed);
        run
    })?;

    let (m, trace) = front.shutdown_traced()?;
    println!("\n== results ==");
    println!("{}", m.report());
    println!(
        "\nend-to-end: {total_tokens} tokens in {wall:.1}s = {:.1} tok/s aggregate \
         ({token_batches} stream batches, {shed} shed at the door)",
        total_tokens as f64 / wall
    );
    if m.shed_requests > 0 || m.cancelled_requests > 0 {
        println!(
            "front door: {} shed (never reached a device), {} cancelled",
            m.shed_requests, m.cancelled_requests
        );
    }

    // reconciliation: the fleet aggregate must equal the sum of the
    // per-cartridge ledgers — the Split-Brain accounting stays per device
    let agg = m.aggregate();
    let sum_requests: u64 = m.cartridges.iter().map(|c| c.serving.requests_completed).sum();
    let sum_bytes: u64 = m.cartridges.iter().map(|c| c.serving.traffic.total()).sum();
    assert_eq!(agg.requests_completed, sum_requests);
    assert_eq!(agg.interface_bytes, sum_bytes);
    println!(
        "reconciled: {} requests, {:.2} MB interface traffic across {} cartridges \
         (per-cartridge ledgers sum exactly)",
        sum_requests,
        sum_bytes as f64 / 1e6,
        m.cartridges.len()
    );
    let total_prompt = agg.tokens_prefilled + agg.prefill_skipped_tokens;
    println!(
        "prefix reuse: {} of {} prompt tokens served from the radix cache ({:.0}%)",
        agg.prefill_skipped_tokens,
        total_prompt,
        100.0 * agg.prefill_skipped_tokens as f64 / total_prompt.max(1) as f64
    );

    if let Some(path) = &trace_path {
        std::fs::write(path, trace.perfetto_json())?;
        println!(
            "\ntrace: {} events ({} dropped) -> {path} (open at ui.perfetto.dev)",
            trace.events.len(),
            trace.dropped
        );
        // flight recorder: the slowest requests, with their full event chains
        for c in trace.request_chains().into_iter().take(3) {
            let waves =
                c.events.iter().filter(|e| e.kind == ita::coordinator::TraceKind::Wave).count();
            println!(
                "  slowest req {}: {:.2} ms end-to-end, {} events, {} waves",
                c.req,
                c.total_us as f64 / 1e3,
                c.events.len(),
                waves
            );
        }
    }
    if let Some(path) = &metrics_path {
        let snap = MetricsRegistry::from_fleet(m).snapshot();
        std::fs::write(path, snap.to_json())?;
        let prom = format!("{path}.prom");
        std::fs::write(&prom, snap.to_prometheus())?;
        println!("metrics: snapshot -> {path} (JSON) + {prom} (Prometheus)");
    }
    Ok(())
}

/// Submit the timed workload through the front door at its declared
/// arrival times, then drain every stream and hold the exactly-once
/// contract. Counters accumulate into the caller's slots so the report
/// survives an early error.
fn run_workload(
    front: &FrontDoor,
    timed: Vec<TimedRequest>,
    t0: Instant,
    shed: &mut usize,
    total_tokens: &mut usize,
    token_batches: &mut usize,
) -> Result<()> {
    let mut streams = Vec::new();
    for tr in timed {
        let wait = tr.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        match front.submit(tr.request) {
            Ok(s) => streams.push(s),
            Err(SubmitError::Overloaded { projected_wait_s, budget_s }) => {
                *shed += 1;
                eprintln!(
                    "[shed] projected queue wait {:.0}ms > budget {:.0}ms",
                    projected_wait_s * 1e3,
                    budget_s * 1e3
                );
            }
            Err(SubmitError::Closed) => bail!("fleet closed during submission"),
        }
    }
    // drain every stream incrementally and hold the front door to its
    // contract: the concatenated stream equals the final result, exactly
    for mut s in streams {
        let mut streamed = Vec::new();
        let result = loop {
            match s.recv() {
                Some(StreamItem::Tokens(t)) => {
                    *token_batches += 1;
                    streamed.extend(t);
                }
                Some(StreamItem::End(r)) => break *r,
                None => bail!("a stream was severed before its request completed"),
            }
        };
        assert_eq!(streamed, result.tokens, "stream must concatenate to the final result");
        *total_tokens += result.tokens.len();
    }
    Ok(())
}

/// Minimal dependency-free HTTP/1.1 responder for the observability plane:
/// `/metrics` (Prometheus text format), `/status` (positional
/// [`StatusSnapshot`](ita::coordinator::StatusSnapshot) JSON), `/trace`
/// (flight-recorder tail JSON). One request per connection, nonblocking
/// accept so the `stop` flag is honoured within ~10 ms.
fn serve_status(listener: std::net::TcpListener, front: &FrontDoor, stop: &AtomicBool) {
    use std::io::{Read as _, Write as _};
    while !stop.load(Ordering::Relaxed) {
        let mut conn = match listener.accept() {
            Ok((c, _)) => c,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(_) => return,
        };
        let _ = conn.set_read_timeout(Some(Duration::from_millis(500)));
        let mut buf = [0u8; 1024];
        let n = conn.read(&mut buf).unwrap_or(0);
        let req = String::from_utf8_lossy(&buf[..n]);
        let path = req.split_whitespace().nth(1).unwrap_or("/");
        let (status_line, content_type, body) = match path {
            "/metrics" => match front.metrics() {
                Ok(m) => (
                    "200 OK",
                    "text/plain; version=0.0.4",
                    MetricsRegistry::from_fleet(m).snapshot().to_prometheus(),
                ),
                Err(e) => ("500 Internal Server Error", "text/plain", e.to_string()),
            },
            "/status" => match front.status() {
                Ok(s) => ("200 OK", "application/json", s.to_json()),
                Err(e) => ("500 Internal Server Error", "text/plain", e.to_string()),
            },
            "/trace" => match front.status() {
                Ok(s) => ("200 OK", "application/json", s.trace_json()),
                Err(e) => ("500 Internal Server Error", "text/plain", e.to_string()),
            },
            _ => ("404 Not Found", "text/plain", "see /metrics /status /trace\n".to_string()),
        };
        let _ = write!(
            conn,
            "HTTP/1.1 {status_line}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
    }
}
