//! §Perf profiling harness: per-block device-call latency and whole-step
//! engine forward latency. `cargo run --release --example profile_device`
use std::time::Instant;
use ita::coordinator::engine::Engine;
use ita::device::ItaDevice;
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::host::embedding::EmbeddingTable;
use ita::model::Mat;
use ita::runtime::weights::load_artifacts;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/demo-100m");
    let (m, s) = load_artifacts(&dir).unwrap();
    let n_heads = m.n_heads;
    let sim = SimDevice::load(&m, &s).unwrap();
    let emb = EmbeddingTable::new(sim.weights().emb.clone());
    let mut dev = PjrtDevice::load(m, &s, "fused").unwrap();
    for b in [1usize, 8] {
        let h = Mat::new(b, 768, (0..b*768).map(|i| (i as f32*0.01).sin()).collect());
        let attn = h.clone();
        for _ in 0..3 { dev.qkv(0, &h).unwrap(); dev.ffn(0, &h, &attn).unwrap(); }
        let n = 20;
        let t0 = Instant::now();
        for _ in 0..n { dev.qkv(0, &h).unwrap(); }
        println!("b={b} qkv:    {:.2} ms/call", t0.elapsed().as_secs_f64()*1e3/n as f64);
        let t0 = Instant::now();
        for _ in 0..n { dev.ffn(0, &h, &attn).unwrap(); }
        println!("b={b} ffn:    {:.2} ms/call", t0.elapsed().as_secs_f64()*1e3/n as f64);
        let t0 = Instant::now();
        for _ in 0..n { dev.logits(&h).unwrap(); }
        println!("b={b} logits: {:.2} ms/call", t0.elapsed().as_secs_f64()*1e3/n as f64);
    }

    // all-layer sweep: does streaming 14 layers of weights (≈350 MB) from
    // DRAM dominate? (the "memory wall" the paper eliminates)
    let h8 = Mat::new(8, 768, (0..8*768).map(|i| (i as f32*0.01).sin()).collect());
    let a8 = h8.clone();
    let t0 = Instant::now();
    let n = 10;
    for _ in 0..n {
        for layer in 0..14 {
            dev.qkv(layer, &h8).unwrap();
            dev.ffn(layer, &h8, &a8).unwrap();
        }
    }
    println!("all-layer qkv+ffn sweep b=8: {:.1} ms/step", t0.elapsed().as_secs_f64()*1e3/n as f64);
    // whole-step engine forward
    let mut engine = Engine::new(Box::new(dev), emb, n_heads);
    let ids: Vec<_> = (0..8).map(|_| engine.new_sequence()).collect();
    let toks = vec![65u32; 8];
    for _ in 0..3 { engine.forward(&ids, &toks).unwrap(); }
    let t0 = Instant::now();
    let n = 20;
    for _ in 0..n { engine.forward(&ids, &toks).unwrap(); }
    println!("engine.forward b=8: {:.1} ms/step", t0.elapsed().as_secs_f64()*1e3/n as f64);
}
