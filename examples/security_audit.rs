//! Security-economics audit (paper Section VI-E / Fig 3): attack-vector
//! costs, the extraction barrier, DPA countermeasure overheads, and the
//! deterrence frontier across model-training-cost classes.
//!
//!     cargo run --release --example security_audit

use ita::security::dpa::{cpa_attack, collect_traces, traces_to_break, DpaParams};
use ita::security::{
    attack_vectors, barrier_ratio, deterrent, extraction_floor_usd, Target,
    DPA_COUNTERMEASURES,
};
use ita::util::benchkit::print_table;
use ita::util::fmt;
use ita::util::prng::Prng;

fn dpa_demo() {
    println!("\n=== DPA simulation (hardwired MAC, Hamming-weight leakage) ===");
    let secret = -6i8;
    let mut rng = Prng::new(0xD9A);
    let (xs, traces) = collect_traces(secret, 256, &DpaParams::unprotected(), &mut rng);
    let (guess, margin) = cpa_attack(&xs, &traces);
    println!(
        "unprotected: CPA over 256 traces recovers w={guess} (secret {secret}), \
         correlation margin {margin:.3}"
    );
    let mut rows = Vec::new();
    for w in [-7i8, -3, 1, 5, 7] {
        let clean = traces_to_break(w, &DpaParams::unprotected(), 1 << 16, 11);
        let masked = traces_to_break(w, &DpaParams::protected(), 1 << 16, 11);
        rows.push(vec![
            format!("{w}"),
            clean.map_or(">65536".into(), |n| n.to_string()),
            masked.map_or(">65536 (never)".into(), |n| n.to_string()),
        ]);
    }
    print_table(
        "Traces to recover one INT4 weight (first-order CPA)",
        &["Weight", "Unprotected", "Masked + noise"],
        &rows,
    );
    println!(
        "  note: boolean masking defeats first-order CPA outright; scaling even the\n\
         \x20       unprotected case to 6.6e9 weights is weeks of physical access —\n\
         \x20       the economics behind the paper's Section VI-E barrier"
    );
}

fn main() {
    println!("ITA security audit\n");

    // attack inventory
    let rows: Vec<Vec<String>> = attack_vectors()
        .iter()
        .map(|a| {
            vec![
                a.name.to_string(),
                format!("{:?}", a.applies_to),
                format!("{} - {}", fmt::dollars(a.equipment_usd.0), fmt::dollars(a.equipment_usd.1)),
                a.rental_usd_per_day
                    .map_or("-".into(), |(lo, hi)| format!("{}-{}/day", fmt::dollars(lo), fmt::dollars(hi))),
                format!("{:.0}-{:.0} days", a.time_days.0, a.time_days.1),
                format!("{:?}", a.skill),
                fmt::dollars(a.min_cost_usd()),
            ]
        })
        .collect();
    print_table(
        "Attack vectors (Section VI-E2)",
        &["Vector", "Target", "Equipment", "Rental", "Time", "Skill", "Min cost"],
        &rows,
    );

    // the barrier
    let sw = extraction_floor_usd(Target::SoftwareReadable).max(2_000.0);
    let hw = extraction_floor_usd(Target::PhysicalLogic);
    println!(
        "\nextraction floor: software-readable {} → ITA {}  (barrier {:.0}x; paper: 25x text, \
         50-500x economic-impact discussion)",
        fmt::dollars(sw),
        fmt::dollars(hw),
        barrier_ratio()
    );

    // countermeasures
    let c = DPA_COUNTERMEASURES;
    println!(
        "\nDPA countermeasures (masking + noise injection): +{:.0}% area, +{:.0}% power, \
         +{} per unit — the paper's own caveat: static weights give repeatable power \
         signatures, so side channels are the cheapest physical attack",
        c.area_overhead * 100.0,
        c.power_overhead * 100.0,
        fmt::dollars(c.unit_cost_usd)
    );

    // deterrence frontier
    let rows: Vec<Vec<String>> = [50_000.0, 500_000.0, 5_000_000.0, 50_000_000.0]
        .iter()
        .map(|&training| {
            vec![
                fmt::dollars(training),
                if deterrent(training, Target::SoftwareReadable) { "yes" } else { "no" }.into(),
                if deterrent(training, Target::PhysicalLogic) { "yes" } else { "no" }.into(),
                if training >= 50_000_000.0 {
                    "add PUF + secure boot (paper's advice)".into()
                } else {
                    "-".to_string()
                },
            ]
        })
        .collect();
    print_table(
        "Deterrence frontier (extraction ≥ 1% of training cost)",
        &["Model training cost", "GPU deters?", "ITA deters?", "Extra"],
        &rows,
    );

    dpa_demo();
}
