//! Schema checker for the observability artifacts: validates a Perfetto
//! trace (and optionally a metrics snapshot) emitted by `serve_fleet`.
//!
//!     cargo run --release --example trace_check -- trace.json [metrics.json]
//!
//! Checks, exiting non-zero on the first violation:
//!
//! * `traceEvents` is a non-empty array and every event carries the
//!   Chrome/Perfetto required fields (`name`, `ph`, `pid`, `tid`, `ts`;
//!   complete events additionally `dur`);
//! * every request's `queued` + `active` span durations sum to the E2E
//!   latency its `complete` event reports, within 3 µs of rounding — the
//!   acceptance rail for the trace: per-request spans account for the
//!   request's entire reported latency;
//! * at least one `wave` span exists (a trace with no device work is a
//!   plumbing bug, not a quiet run);
//! * the metrics snapshot (if given) exposes the aggregate keys the
//!   dashboards scrape: `requests_completed`, `energy_j`,
//!   `queue_wait_p50_s`, `queue_wait_p99_s`, `joules_per_token`.
//!
//! Used by `make trace-check` and the CI bench-smoke job; the invariants it
//! pins are documented in `docs/observability.md`.

use anyhow::{bail, Context, Result};

use ita::util::json::{parse, JsonValue};

/// Span/arg accounting for one traced request.
#[derive(Default)]
struct ReqCheck {
    queued_us: u64,
    active_us: u64,
    total_us: Option<u64>,
}

fn field<'a>(ev: &'a JsonValue, key: &str, i: usize) -> Result<&'a JsonValue> {
    ev.get(key).with_context(|| format!("event {i} missing required field {key:?}"))
}

fn check_trace(text: &str) -> Result<(usize, usize)> {
    let root = parse(text).context("trace is not valid JSON")?;
    let events = root
        .get("traceEvents")
        .and_then(JsonValue::as_array)
        .context("root has no traceEvents array")?;
    if events.is_empty() {
        bail!("traceEvents is empty");
    }

    let mut reqs: std::collections::BTreeMap<u64, ReqCheck> = Default::default();
    let mut waves = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let name = field(ev, "name", i)?.as_str().context("name is not a string")?;
        let ph = field(ev, "ph", i)?.as_str().context("ph is not a string")?;
        field(ev, "pid", i)?.as_f64().context("pid is not a number")?;
        field(ev, "tid", i)?.as_f64().context("tid is not a number")?;
        if ph == "M" {
            continue; // metadata events carry no timestamp semantics
        }
        field(ev, "ts", i)?.as_f64().context("ts is not a number")?;
        let dur = match ph {
            "X" => Some(
                field(ev, "dur", i)?.as_f64().context("dur is not a number")? as u64,
            ),
            "i" => None,
            other => bail!("event {i} has unexpected phase {other:?}"),
        };
        if name == "wave" {
            waves += 1;
        }
        let Some(req) = ev.get("args").and_then(|a| a.get("req")).and_then(JsonValue::as_f64)
        else {
            continue;
        };
        let entry = reqs.entry(req as u64).or_default();
        match name {
            "queued" => entry.queued_us += dur.unwrap_or(0),
            "active" => entry.active_us += dur.unwrap_or(0),
            "complete" => {
                entry.total_us = ev
                    .get("args")
                    .and_then(|a| a.get("total_us"))
                    .and_then(JsonValue::as_f64)
                    .map(|v| v as u64)
            }
            _ => {}
        }
    }
    if waves == 0 {
        bail!("trace has no wave spans");
    }

    // the acceptance rail: queued + active account for the reported E2E
    // latency of every completed request, within span-rounding tolerance
    let mut completed = 0usize;
    for (req, c) in &reqs {
        let Some(total) = c.total_us else { continue };
        completed += 1;
        let sum = c.queued_us + c.active_us;
        let gap = sum.abs_diff(total);
        if gap > 3 {
            bail!(
                "req {req}: queued {} + active {} = {sum} µs, but complete reports \
                 {total} µs (gap {gap} µs > 3 µs tolerance)",
                c.queued_us,
                c.active_us
            );
        }
    }
    if completed == 0 {
        bail!("no request in the trace carries a complete event");
    }
    Ok((events.len(), completed))
}

fn check_metrics(text: &str) -> Result<()> {
    let root = parse(text).context("metrics snapshot is not valid JSON")?;
    match root.get("schema").and_then(JsonValue::as_str) {
        Some("ita-metrics-v1") => {}
        other => bail!("unexpected metrics schema {other:?}"),
    }
    let agg = root.get("aggregate").context("snapshot has no aggregate object")?;
    let keys = [
        "requests_completed",
        "energy_j",
        "queue_wait_p50_s",
        "queue_wait_p99_s",
        "joules_per_token",
    ];
    for key in keys {
        if agg.get(key).is_none() {
            bail!("aggregate is missing {key:?}");
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let trace_path = args.get(1).map(String::as_str).unwrap_or("trace.json");
    let text = std::fs::read_to_string(trace_path)
        .with_context(|| format!("reading {trace_path}"))?;
    let (events, completed) = check_trace(&text)?;
    println!("trace-check: {trace_path} ok ({events} events, {completed} completed requests)");
    if let Some(metrics_path) = args.get(2) {
        let text = std::fs::read_to_string(metrics_path)
            .with_context(|| format!("reading {metrics_path}"))?;
        check_metrics(&text)?;
        println!("trace-check: {metrics_path} ok (ita-metrics-v1 aggregate keys present)");
    }
    Ok(())
}
