//! Quickstart: generate text through the full Split-Brain stack on the
//! `tiny` cartridge (weights baked into the HLO as compile-time constants —
//! the literal One-Model-One-Chip artifact).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The flow (paper Fig. 1): host tokenizes and embeds; for every layer the
//! ITA device computes QKV (hardwired weights), the host applies RoPE,
//! appends K/V to the paged cache and runs causal attention, the device
//! runs Wo + SwiGLU FFN; the device emits logits; the host samples.

use std::path::PathBuf;

use anyhow::Result;

use ita::coordinator::engine::Engine;
use ita::coordinator::request::GenRequest;
use ita::coordinator::scheduler::{Scheduler, SchedulerOpts};
use ita::device::pjrt::PjrtDevice;
use ita::device::sim::SimDevice;
use ita::host::embedding::EmbeddingTable;
use ita::runtime::weights::load_artifacts;

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    anyhow::ensure!(
        dir.join("MANIFEST.txt").exists(),
        "artifacts/tiny missing — run `make artifacts` first"
    );

    // 1. load the cartridge: manifest + weight blobs (host embedding only —
    //    the device's weights are *inside* the HLO programs)
    let (manifest, store) = load_artifacts(&dir)?;
    println!(
        "cartridge `{}`: {} layers, d_model {}, {} programs, {:.1}% weights pruned",
        manifest.config_name,
        manifest.n_layers,
        manifest.d_model,
        manifest.programs.len(),
        manifest.pruned_fraction * 100.0
    );

    // 2. bring up the ITA device on the PJRT CPU client
    let n_heads = manifest.n_heads;
    let sim = SimDevice::load(&manifest, &store)?; // embedding table source
    let emb = EmbeddingTable::new(sim.weights().emb.clone());
    let device = PjrtDevice::load(manifest, &store, "fused")?;
    println!(
        "device up: platform={}, {} compiled programs",
        device.runtime().platform(),
        device.runtime().n_programs()
    );

    // 3. split-brain engine + scheduler
    let engine = Engine::new(Box::new(device), emb, n_heads);
    let mut sched = Scheduler::new(engine, SchedulerOpts::default());

    // 4. generate (weights are synthetic, so the text is gibberish — the
    //    point is the full pipeline: every byte of model weights lives in
    //    the immutable artifact, every byte of dynamic state on the host)
    sched.submit(GenRequest::greedy(0, "The Immutable Tensor Architecture", 24));
    let results = sched.run_to_completion()?;
    let r = &results[0];
    println!("\nprompt tokens: {}", r.prompt_tokens);
    println!("generated {} tokens: {:?}", r.tokens.len(), &r.tokens);
    println!("ttft: {:.1} ms, mean itl: {:.2} ms", r.ttft_s * 1e3, r.itl_s * 1e3);

    let m = sched.metrics();
    println!("\n{}", m.report());
    println!(
        "modeled device energy: {:.3} mJ ({:.2} pJ/MAC, Table II)",
        m.modeled_device_energy_j(4.05) * 1e3,
        4.05
    );
    Ok(())
}
