//! Design-space sweeps over the analytical models — the ablations DESIGN.md
//! calls out for the paper's main design choices:
//!
//! 1. weight bit-width (INT2..INT8) vs per-MAC gates & die area,
//! 2. routing-overhead sensitivity (the paper's 1.4x vs 3.0x caveat),
//! 3. interface choice vs achievable throughput at several host-attention
//!    speeds (the Section VI-C "attention bottleneck" picture),
//! 4. batch-bucket sets vs padding waste for the serving batcher.
//!
//!     cargo run --release --example design_space

use ita::area::{self, Routing};
use ita::config::{ModelConfig, TechParams};
use ita::coordinator::batcher;
use ita::cost::unit_cost;
use ita::interface::{token_latency, Link, TokenTraffic};
use ita::synth::gates::CellCosts;
use ita::synth::{multiplier, shift_add};
use ita::util::benchkit::print_table;
use ita::util::prng::Prng;

fn sweep_weight_bits() {
    let costs = CellCosts::asic_28nm();
    let mut rows = Vec::new();
    for bits in [2u32, 3, 4, 5, 6, 8] {
        // expected hardwired cost over a synthetic sample at this width
        let mut rng = Prng::new(bits as u64);
        let k = 512;
        let mut sample = Vec::with_capacity(4096);
        while sample.len() < 4096 {
            let col: Vec<f32> =
                (0..k).map(|_| rng.normal() as f32 / (k as f32).sqrt()).collect();
            let (q, _) = ita::quant::quantize_weights(&col, k, 1, bits, true);
            sample.extend_from_slice(&q);
        }
        let hw = shift_add::expected_hardwired_cost(&sample, 8, 24, &costs);
        let generic = multiplier::generic_mac(8, bits, 24).total(&costs);
        // die area at this width (7B topology)
        let mut tech = TechParams::paper_28nm();
        let cfg = ModelConfig::LLAMA2_7B;
        let bits_total = cfg.params() as f64 * bits as f64;
        let raw = bits_total * tech.storage_um2_per_bit / 1e6;
        tech.routing_overhead = 1.4;
        let final_mm2 = raw * 1.4 * 1.15 * tech.synthesis_opt;
        rows.push(vec![
            format!("INT{bits}"),
            format!("{:.0}", generic),
            format!("{:.0}", hw),
            format!("{:.2}x", generic / hw),
            format!("{:.0}", final_mm2),
            format!("{:.1}%", ita::quant::pruned_fraction(&sample) * 100.0),
        ]);
    }
    print_table(
        "Sweep 1 — weight width vs MAC gates & 7B die area",
        &["Width", "Generic MAC", "ITA MAC (exp)", "Reduction", "7B die mm²", "Pruned"],
        &rows,
    );
    println!("  note: INT4 is the paper's sweet spot — below it pruning destroys accuracy\n        headroom, above it area scales linearly with bits");
}

fn sweep_routing() {
    let tech = TechParams::paper_28nm();
    let mut rows = Vec::new();
    for routing in [1.0, 1.4, 2.0, 3.0, 4.0] {
        let mut t = tech.clone();
        t.routing_overhead = routing;
        let est = area::estimate(&ModelConfig::LLAMA2_7B, &t, Routing::Optimistic);
        let u = unit_cost(&est, &t);
        rows.push(vec![
            format!("{routing:.1}x"),
            format!("{:.0}", est.final_mm2),
            format!("{}", est.n_chiplets),
            ita::util::fmt::dollars(u.total()),
        ]);
    }
    print_table(
        "Sweep 2 — routing-overhead sensitivity (Llama-2-7B)",
        &["Routing", "Silicon mm²", "Chiplets", "Unit cost"],
        &rows,
    );
    println!("  note: the paper's optimistic/conservative scenarios are the 1.4x and 3.0x rows");
}

fn sweep_attention_bottleneck() {
    let traffic = TokenTraffic::paper_mode(&ModelConfig::LLAMA2_7B);
    let mut rows = Vec::new();
    for (label, att_s) in [
        ("NPU offload (5 ms)", 5e-3),
        ("fast CPU (20 ms)", 20e-3),
        ("laptop CPU (50 ms)", 50e-3),
        ("slow CPU (100 ms)", 100e-3),
    ] {
        let mut row = vec![label.to_string()];
        for link in Link::ALL {
            let lat = token_latency(&traffic, &link, att_s);
            row.push(format!("{:.0}", lat.tokens_per_s()));
        }
        rows.push(row);
    }
    print_table(
        "Sweep 3 — tok/s by link × host attention speed (7B)",
        &["Host attention", "PCIe3x4", "TB4", "USB3", "USB4"],
        &rows,
    );
    println!("  note: once attention exceeds ~20 ms the link stops mattering — the paper's\n        'attention bottleneck' (Section VI-C2) in one table");
}

fn sweep_buckets() {
    let sets: [&[usize]; 4] = [&[1], &[1, 8], &[1, 2, 4, 8], &[1, 2, 3, 4, 5, 6, 7, 8]];
    let mut rows = Vec::new();
    for buckets in sets {
        let mut stats = batcher::BatchStats::default();
        for n in 1..=64usize {
            stats.record(&batcher::plan(n, buckets));
        }
        rows.push(vec![
            format!("{buckets:?}"),
            format!("{:.1}%", stats.waste() * 100.0),
            format!("{}", buckets.len()),
        ]);
    }
    print_table(
        "Sweep 4 — batch-bucket set vs padding waste (uniform 1..64 load)",
        &["Buckets", "Padded rows", "Programs compiled"],
        &rows,
    );
    println!("  note: more buckets -> less padding but more AOT programs; {{1,2,4,8}} is the default");
}

fn main() {
    println!("ITA design-space ablations\n");
    sweep_weight_bits();
    println!();
    sweep_routing();
    println!();
    sweep_attention_bottleneck();
    println!();
    sweep_buckets();
}
